"""Unified quantization API: Recipe -> Artifact -> Runtime.

Contracts under test:
  * every registered method produces the same artifact type through
    ``quantize`` and evaluates through the same Runtime path;
  * ``save``/``load`` round-trips bit-exactly — planes/scales/bias/sat for
    per-channel, batched (>2-dim expert/scanned) and packed-INT4 leaves;
  * a loaded artifact's ``Runtime.apply`` matches the in-memory one
    bit-exactly (the ISSUE acceptance criterion) for all three methods;
  * serving admission by artifact does not re-expand;
  * pack_int4 handles odd last axes via the recorded pad nibble.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (QuantArtifact, QuantRecipe, Runtime, list_methods,
                       named_recipe, quantize, recipe_from_dict,
                       recipe_to_dict, register_quantizer)
from repro.configs.base import get_arch
from repro.core import expansion as E
from repro.core.expansion import ExpandedTensor
from repro.core.policy import ExpansionPolicy, W4A4, W4A16
from repro.models import model as M

METHODS = ("fpxint", "rtn", "gptq_lite")


def _toy_params(rng):
    r = np.random.default_rng(0)
    return {
        "embed": {"embedding": jnp.array(r.normal(size=(64, 16)).astype(np.float32))},
        "stages": {"b0_attn": {"attn": {"q": {"kernel": jnp.array(
            r.normal(size=(2, 16, 16)).astype(np.float32))}},
            "ln": {"scale": jnp.ones((2, 16))}}},
        "lm_head": {"kernel": jnp.array(r.normal(size=(16, 64)).astype(np.float32))},
    }


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a, is_leaf=lambda l: isinstance(l, ExpandedTensor))
    lb = jax.tree_util.tree_leaves(b, is_leaf=lambda l: isinstance(l, ExpandedTensor))
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if isinstance(x, ExpandedTensor):
            assert isinstance(y, ExpandedTensor)
            assert (x.bits, x.per_channel, x.batch_dims, x.packed, x.pack_pad) \
                == (y.bits, y.per_channel, y.batch_dims, y.packed, y.pack_pad)
            for f in ("planes", "scales", "bias", "sat"):
                xa, ya = getattr(x, f), getattr(y, f)
                assert (xa is None) == (ya is None), f
                if xa is not None:
                    np.testing.assert_array_equal(np.asarray(xa), np.asarray(ya))
                    assert xa.dtype == ya.dtype
        else:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# registry / recipe
# ---------------------------------------------------------------------------
def test_registry_has_builtin_methods():
    assert set(METHODS) <= set(list_methods())


def test_unknown_method_raises():
    with pytest.raises(KeyError):
        QuantRecipe(method="nope")


def test_pack_requires_low_bits():
    with pytest.raises(ValueError):
        QuantRecipe(method="fpxint", policy=ExpansionPolicy(w_bits=8), pack=True)


def test_pack_requires_series_method():
    """pack=True on an FP-reconstruction method is rejected up front (the
    method would silently ignore it and pallas-packed would refuse later)."""
    with pytest.raises(ValueError):
        QuantRecipe(method="rtn", policy=W4A4, pack=True)


def test_recipe_json_roundtrip():
    pol = ExpansionPolicy(w_bits=2, mixed=(("attn", (2, 4)),))
    r = QuantRecipe(method="fpxint", policy=pol, pack=True, arch="qwen2_1_5b")
    r2 = recipe_from_dict(recipe_to_dict(r))
    assert r2 == r
    assert hash(r2) == hash(r)              # stays hashable (static jit arg)


def test_named_recipe():
    r = named_recipe("w4a16", method="fpxint")
    assert r.policy == W4A16


def test_register_custom_quantizer(rng):
    @register_quantizer("identity_test")
    def _identity(params, recipe):
        return params, {"expanded": False}
    try:
        art = quantize(_toy_params(rng), QuantRecipe(method="identity_test"))
        assert isinstance(art, QuantArtifact)
        assert art.method == "identity_test"
    finally:
        from repro.api.recipe import QUANTIZERS
        del QUANTIZERS["identity_test"]


# ---------------------------------------------------------------------------
# quantize: one artifact type for every method
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", METHODS)
def test_quantize_produces_artifact(rng, method):
    art = quantize(_toy_params(rng), QuantRecipe(method=method, policy=W4A4))
    assert isinstance(art, QuantArtifact)
    assert art.quant_seconds > 0.0
    assert "expansion_stats" in art.meta
    if method == "fpxint":
        assert art.expanded
        assert art.leaf_table()              # per-leaf bits/terms provenance
        entry = art.leaf_table()["lm_head/kernel"]
        assert entry["bits"] == 8            # first/last protection recorded
    else:
        assert not art.expanded
        # baselines reconstruct in FP: same tree structure as the input
        assert isinstance(art.params["lm_head"]["kernel"], jnp.ndarray)


def test_provenance_batched_leaf(rng):
    art = quantize(_toy_params(rng), QuantRecipe(method="fpxint", policy=W4A4))
    entry = art.leaf_table()["stages/b0_attn/attn/q/kernel"]
    assert entry["batch_dims"] == 1 and entry["terms"] == 2


# ---------------------------------------------------------------------------
# save / load bit-exactness
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", METHODS)
def test_save_load_roundtrip(rng, tmp_path, method):
    art = quantize(_toy_params(rng), QuantRecipe(method=method, policy=W4A4))
    path = str(tmp_path / method)
    art.save(path)
    art2 = QuantArtifact.load(path)
    assert art2.recipe == art.recipe
    assert art2.meta["method"] == method
    _assert_trees_equal(art.params, art2.params)


def test_save_load_per_channel_bias_sat(rng, tmp_path):
    """Asymmetric saturating per-channel expansion: bias and sat present."""
    pol = ExpansionPolicy(w_bits=4, w_symmetric=False, w_saturating=True,
                          keep_w_sat=True, w_per_channel=True)
    art = quantize(_toy_params(rng), QuantRecipe(method="fpxint", policy=pol))
    et = art.params["lm_head"]["kernel"]
    assert et.bias is not None and et.sat is not None
    art.save(str(tmp_path / "a"))
    _assert_trees_equal(art.params, QuantArtifact.load(str(tmp_path / "a")).params)


def test_save_load_packed(rng, tmp_path):
    art = quantize(_toy_params(rng),
                   QuantRecipe(method="fpxint", policy=W4A4, pack=True))
    assert art.packed
    et = art.params["stages"]["b0_attn"]["attn"]["q"]["kernel"]
    assert et.packed and et.planes.shape[-1] == 8      # 16 cols -> 8 bytes
    art.save(str(tmp_path / "p"))
    art2 = QuantArtifact.load(str(tmp_path / "p"))
    _assert_trees_equal(art.params, art2.params)
    # unpacked view identical to an unpacked quantize of the same params
    plain = quantize(_toy_params(rng), QuantRecipe(
        method="fpxint", policy=dataclasses.replace(W4A4, pack_safe=True)))
    _assert_trees_equal(art2.runtime_params("ref"), plain.params)


def test_save_load_packed_odd_axis(tmp_path):
    """Odd last axis: the pad nibble is recorded and stripped exactly."""
    r = np.random.default_rng(3)
    params = {"fc": {"kernel": jnp.array(r.normal(size=(16, 33)).astype(np.float32))}}
    pol = ExpansionPolicy(w_bits=4, first_last_bits=4)   # no 8-bit protection
    art = quantize(params, QuantRecipe(method="fpxint", policy=pol, pack=True))
    et = art.params["fc"]["kernel"]
    assert et.packed and et.pack_pad == 1 and et.planes.shape[-1] == 17
    assert et.orig_shape == (16, 33)
    art.save(str(tmp_path / "odd"))
    art2 = QuantArtifact.load(str(tmp_path / "odd"))
    _assert_trees_equal(art.params, art2.params)
    up = art2.runtime_params("ref")["fc"]["kernel"]
    assert up.planes.shape == (2, 16, 33)
    np.testing.assert_array_equal(
        np.asarray(E.reconstruct(art.params["fc"]["kernel"])),
        np.asarray(E.reconstruct(up)))


def test_save_load_empty_containers(tmp_path):
    """Empty subtrees (parameterless modules) survive the round-trip with
    identical pytree structure."""
    r = np.random.default_rng(0)
    params = {"a": {"kernel": jnp.array(r.normal(size=(8, 8)).astype(np.float32))},
              "empty_mod": {}, "empty_list": []}
    art = quantize(params, QuantRecipe(method="fpxint", policy=W4A4))
    art.save(str(tmp_path / "e"))
    loaded = QuantArtifact.load(str(tmp_path / "e")).params
    assert loaded["empty_mod"] == {} and loaded["empty_list"] == []
    assert (jax.tree_util.tree_structure(loaded)
            == jax.tree_util.tree_structure(art.params))


def test_load_uncommitted_raises(tmp_path):
    os.makedirs(tmp_path / "torn")
    with pytest.raises(FileNotFoundError):
        QuantArtifact.load(str(tmp_path / "torn"))


def test_save_is_atomic_replace(rng, tmp_path):
    """Re-saving over an existing artifact replaces it committed-or-nothing."""
    art = quantize(_toy_params(rng), QuantRecipe(method="fpxint", policy=W4A4))
    path = str(tmp_path / "a")
    art.save(path)
    art.save(path)                                      # overwrite in place
    assert os.path.exists(os.path.join(path, ".DONE"))
    assert not os.path.exists(path + ".tmp")
    assert not os.path.exists(path + ".old")            # staging cleaned up
    _assert_trees_equal(art.params, QuantArtifact.load(path).params)


# ---------------------------------------------------------------------------
# Runtime: loaded artifact == in-memory artifact (model level)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def model_setup():
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.array(np.random.default_rng(7).integers(
        0, cfg.vocab_size, (2, 12)), jnp.int32)
    return cfg, params, tokens


@pytest.mark.parametrize("method", METHODS)
def test_runtime_apply_bit_exact_after_load(model_setup, tmp_path, method):
    cfg, params, tokens = model_setup
    art = quantize(params, QuantRecipe(method=method, policy=W4A4,
                                       arch="qwen2_1_5b", smoke=True))
    y_mem = Runtime(art, backend="ref", cfg=cfg).apply(tokens)
    art.save(str(tmp_path / method))
    y_disk = Runtime(QuantArtifact.load(str(tmp_path / method)),
                     backend="ref").apply(tokens)      # cfg from the recipe
    np.testing.assert_array_equal(np.asarray(y_mem), np.asarray(y_disk))


def test_runtime_lm_loss(model_setup):
    cfg, params, _ = model_setup
    from repro.train.data import make_batch
    art = quantize(params, QuantRecipe(method="fpxint", policy=W4A4,
                                       arch="qwen2_1_5b"))
    l, m = Runtime(art, backend="ref", cfg=cfg).lm_loss(make_batch(cfg, 32, 2, 0))
    assert np.isfinite(float(l)) and 0.0 <= float(m["accuracy"]) <= 1.0


def test_runtime_backend_validation(rng):
    art = quantize(_toy_params(rng), QuantRecipe(method="rtn", policy=W4A4))
    with pytest.raises(ValueError):
        Runtime(art, backend="pallas")        # FP reconstruction: ref only
    with pytest.raises(ValueError):
        Runtime(art, backend="bogus")
    art_fp = quantize(_toy_params(rng), QuantRecipe(method="fpxint", policy=W4A4))
    with pytest.raises(ValueError):
        art_fp.runtime_params("pallas-packed")  # needs pack=True at quantize
    # packed W4A4 (activation-quantized): packed storage is fine, but the
    # packed *backend* is weight-only — the series GEMM would re-unpack
    # in-graph per call
    art_pk = quantize(_toy_params(rng),
                      QuantRecipe(method="fpxint", policy=W4A4, pack=True))
    with pytest.raises(ValueError, match="weight-only"):
        art_pk.runtime_params("pallas-packed")


def test_runtime_without_arch_raises(rng):
    art = quantize(_toy_params(rng), QuantRecipe(method="fpxint", policy=W4A4))
    rt = Runtime(art, backend="ref")
    with pytest.raises(ValueError):
        rt.apply(jnp.zeros((1, 4), jnp.int32))


def test_runtime_packed_weight_only(model_setup, tmp_path):
    """W4A16 packed artifact: pallas-packed serves planes 2/byte in place and
    agrees with the ref backend at f32-accumulation tolerance."""
    cfg, params, tokens = model_setup
    art = quantize(params, QuantRecipe(method="fpxint", policy=W4A16,
                                       pack=True, arch="qwen2_1_5b"))
    art.save(str(tmp_path / "packed"))
    art = QuantArtifact.load(str(tmp_path / "packed"))
    y_ref = Runtime(art, backend="ref", cfg=cfg).apply(tokens)
    rt_packed = Runtime(art, backend="pallas-packed", cfg=cfg)
    # the packed runtime binds the packed planes themselves
    leaf = rt_packed.params["stages"]["b0_attn"]["attn"]["q"]["kernel"]
    assert leaf.packed
    y_packed = rt_packed.apply(tokens)
    np.testing.assert_allclose(np.asarray(y_packed), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# serving admission by artifact
# ---------------------------------------------------------------------------
def test_engine_admits_artifact_without_reexpansion(model_setup, tmp_path, monkeypatch):
    cfg, params, _ = model_setup
    art = quantize(params, QuantRecipe(method="fpxint", policy=W4A4,
                                       arch="qwen2_1_5b", smoke=True))
    art.save(str(tmp_path / "srv"))
    loaded = QuantArtifact.load(str(tmp_path / "srv"))

    from repro.core import ptq as PTQ
    def boom(*a, **k):
        raise AssertionError("admission must not re-expand")
    monkeypatch.setattr(PTQ, "expand_params", boom)

    from repro.infer.serve import Engine, ServeConfig
    eng = Engine(cfg, artifact=loaded, backend="ref",
                 serve_cfg=ServeConfig(max_seq=32, max_batch=2))
    assert eng.quant_seconds == loaded.quant_seconds
    rid = eng.add_request(list(range(8)))
    out = eng.run(max_new_tokens=3)
    assert len(out[rid]) == 3


def test_engine_rejects_ambiguous_admission(model_setup):
    cfg, params, _ = model_setup
    art = quantize(params, QuantRecipe(method="fpxint", policy=W4A4,
                                       arch="qwen2_1_5b"))
    from repro.infer.serve import Engine
    with pytest.raises(ValueError):
        Engine(cfg, params, artifact=art)


def test_runtime_serve_matches_legacy_engine(model_setup):
    """Artifact-admitted serving generates exactly what the legacy
    expand-at-admission engine generates (greedy)."""
    cfg, params, _ = model_setup
    from repro.infer.serve import Engine, ServeConfig
    sc = ServeConfig(max_seq=32, max_batch=2)
    prompts = [list(range(8)), list(range(3, 11))]

    legacy = Engine(cfg, params, policy=W4A4, serve_cfg=sc)
    ids_l = [legacy.add_request(p) for p in prompts]
    out_l = legacy.run(max_new_tokens=4)

    art = quantize(params, QuantRecipe(method="fpxint", policy=W4A4,
                                       arch="qwen2_1_5b", smoke=True))
    eng = Runtime(art, backend="ref", cfg=cfg).serve(sc)
    ids_a = [eng.add_request(p) for p in prompts]
    out_a = eng.run(max_new_tokens=4)
    for a, b in zip(ids_l, ids_a):
        assert out_l[a] == out_a[b]
