"""INT4 packing: exact roundtrip + series-matmul equivalence through packing."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import expansion as E
from repro.kernels import ref
from repro.kernels.pack import pack_int4, packed_bytes, unpack_int4


def test_roundtrip_exact(rng):
    planes = jnp.array(rng.integers(-8, 8, (3, 16, 32)), jnp.int8)
    packed = pack_int4(planes)
    assert packed.shape == (3, 16, 16)
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed)), np.asarray(planes))


def test_expanded_planes_roundtrip(rng):
    """pack_safe series planes (true X-bit grid) survive packing bit-exactly,
    and the pack_safe residual bound only loosens by the documented 3x."""
    for bits in (2, 3, 4):
        w = jnp.array(rng.normal(size=(32, 64)).astype(np.float32))
        et = E.expand(w, bits, 2, per_channel=True, pack_safe=True)
        assert int(np.abs(np.asarray(et.planes)).max()) <= 2 ** (bits - 1) - 1
        rt = unpack_int4(pack_int4(et.planes))
        np.testing.assert_array_equal(np.asarray(rt), np.asarray(et.planes))
        res = float(jnp.max(jnp.abs(E.residual(w, et))))
        assert res <= 3.0 * float(E.theoretical_residual_bound(et))


def test_series_matmul_through_packed_planes(rng):
    x = jnp.array(rng.normal(size=(8, 32)).astype(np.float32))
    w = jnp.array(rng.normal(size=(32, 16)).astype(np.float32))
    et = E.expand(w, 4, 2, per_channel=True, pack_safe=True)
    s1 = E.first_scale(jnp.max(jnp.abs(x)), 4)
    ws = et.scales
    y_ref = ref.series_matmul_ref(x, s1, et.planes, ws, a_bits=4, a_terms=2)
    y_packed = ref.series_matmul_ref(x, s1, unpack_int4(pack_int4(et.planes)), ws,
                                     a_bits=4, a_terms=2)
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_packed))


def test_storage_halves():
    planes = jnp.zeros((2, 128, 256), jnp.int8)
    assert packed_bytes(planes, 4) == planes.size // 2
    assert packed_bytes(planes, 8) == planes.size


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), rows=st.integers(1, 16),
       cols=st.integers(1, 16))
def test_property_pack_roundtrip(seed, rows, cols):
    r = np.random.default_rng(seed)
    planes = jnp.array(r.integers(-8, 8, (rows, cols * 2)), jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(unpack_int4(pack_int4(planes))), np.asarray(planes))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), rows=st.integers(1, 16),
       cols=st.integers(1, 31))
def test_property_pack_roundtrip_any_width(seed, rows, cols):
    """Odd last axes pack via one pad nibble; unpack strips it exactly."""
    from repro.kernels.pack import pack_pad_nibbles
    r = np.random.default_rng(seed)
    planes = jnp.array(r.integers(-8, 8, (rows, cols)), jnp.int8)
    packed = pack_int4(planes)
    assert packed.shape[-1] == (cols + 1) // 2
    assert pack_pad_nibbles(cols) == cols % 2
    np.testing.assert_array_equal(
        np.asarray(unpack_int4(packed, orig_cols=cols)), np.asarray(planes))


def test_expanded_pack_unpack_helpers(rng):
    """E.pack/E.unpack round-trip an ExpandedTensor incl. odd widths, and
    reconstruct() reads packed tensors transparently."""
    for n in (32, 33):
        w = jnp.array(rng.normal(size=(16, n)).astype(np.float32))
        et = E.expand(w, 4, 2, per_channel=True, pack_safe=True)
        pe = E.pack(et)
        assert pe.packed and pe.orig_shape == (16, n)
        assert pe.pack_pad == n % 2
        np.testing.assert_array_equal(
            np.asarray(E.reconstruct(pe)), np.asarray(E.reconstruct(et)))
        ue = E.unpack(pe)
        np.testing.assert_array_equal(np.asarray(ue.planes), np.asarray(et.planes))
    import pytest
    with pytest.raises(ValueError):
        E.pack(E.expand(w, 8, 1))             # 8-bit planes don't pack
    # non-pack-safe extraction can reach +8, which the nibble mask would
    # wrap to -8 — pack() must refuse rather than corrupt
    import dataclasses
    et8 = dataclasses.replace(et, planes=jnp.full_like(et.planes, 8))
    with pytest.raises(ValueError):
        E.pack(et8)


def test_packed_dequant_matmul_kernel(rng):
    """Pallas packed-INT4 GEMM == unpacked jnp oracle across shapes."""
    from repro.kernels import ops
    for m, k, n in ((8, 32, 16), (64, 128, 96), (33, 65, 34)):
        x = jnp.array(rng.normal(size=(m, k)).astype(np.float32))
        w = jnp.array(rng.normal(size=(k, n)).astype(np.float32))
        et = E.expand(w, 4, 2, per_channel=True, pack_safe=True)
        packed = pack_int4(et.planes)
        yk = ops.packed_dequant_matmul(x, packed, et.scales, use_kernel=True)
        yr = ops.packed_dequant_matmul(x, packed, et.scales, use_kernel=False)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-5, atol=1e-5)
        # and it approximates the fp matmul at the W4 error level
        rel = float(jnp.linalg.norm(yk - x @ w) / jnp.linalg.norm(x @ w))
        assert rel < 0.02, rel
