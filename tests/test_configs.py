"""Assigned configs: exact hyperparameters + analytic size sanity."""
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, applicable_shapes, get_arch

# (arch, expected_total_params, tolerance) — vs published sizes
SIZES = {
    "grok_1_314b": (314e9, 0.15),
    "llama4_scout_17b_a16e": (109e9, 0.30),   # 109B total / 17B active
    "recurrentgemma_9b": (9e9, 0.35),
    "deepseek_7b": (7e9, 0.15),
    "granite_20b": (20e9, 0.20),
    "qwen2_1_5b": (1.5e9, 0.25),
    "nemotron_4_340b": (340e9, 0.15),
    "mamba2_780m": (0.78e9, 0.25),
    "llama_3_2_vision_90b": (88e9, 0.25),
    "hubert_xlarge": (0.96e9, 0.25),
}

EXACT = {
    "grok_1_314b": dict(num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
                        d_ff=32768, vocab_size=131072, num_experts=8, experts_per_token=2),
    "llama4_scout_17b_a16e": dict(num_layers=48, d_model=5120, num_heads=40,
                                  num_kv_heads=8, d_ff=8192, vocab_size=202048,
                                  num_experts=16, experts_per_token=1),
    "recurrentgemma_9b": dict(num_layers=38, d_model=4096, num_heads=16,
                              num_kv_heads=1, d_ff=12288, vocab_size=256000),
    "deepseek_7b": dict(num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
                        d_ff=11008, vocab_size=102400),
    "granite_20b": dict(num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
                        d_ff=24576, vocab_size=49152),
    "qwen2_1_5b": dict(num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
                       d_ff=8960, vocab_size=151936, qkv_bias=True),
    "nemotron_4_340b": dict(num_layers=96, d_model=18432, num_heads=96,
                            num_kv_heads=8, d_ff=73728, vocab_size=256000,
                            mlp_act="relu2"),
    "mamba2_780m": dict(num_layers=48, d_model=1536, ssm_state=128, vocab_size=50280),
    "llama_3_2_vision_90b": dict(num_layers=100, d_model=8192, num_heads=64,
                                 num_kv_heads=8, d_ff=28672, vocab_size=128256),
    "hubert_xlarge": dict(num_layers=48, d_model=1280, num_heads=16,
                          num_kv_heads=16, d_ff=5120, vocab_size=504,
                          is_encoder=True),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_assigned_config(arch):
    cfg = get_arch(arch)
    for k, v in EXACT[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_vs_published(arch):
    cfg = get_arch(arch)
    expect, tol = SIZES[arch]
    n = cfg.param_count()
    assert abs(n - expect) / expect < tol, (arch, n, expect)


def test_shape_cells():
    cells = {(a, s) for a in ARCH_IDS for s in applicable_shapes(get_arch(a))}
    assert len(cells) == 31
    # encoder-only: no decode shapes
    assert ("hubert_xlarge", "decode_32k") not in cells
    assert ("hubert_xlarge", "long_500k") not in cells
    # long_500k only for sub-quadratic archs
    longs = {a for (a, s) in cells if s == "long_500k"}
    assert longs == {"mamba2_780m", "recurrentgemma_9b"}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_same_family(arch):
    full, smoke = get_arch(arch), get_arch(arch, smoke=True)
    assert full.family == smoke.family
    assert full.stage_pattern == smoke.stage_pattern
    assert (full.num_experts > 0) == (smoke.num_experts > 0)
    assert full.is_encoder == smoke.is_encoder
    assert smoke.param_count() < 1e7


def test_moe_active_params():
    g = get_arch("grok_1_314b")
    assert g.active_param_count() < g.param_count()
    d = get_arch("deepseek_7b")
    assert d.active_param_count() == d.param_count()


# ---------------------------------------------------------------------------
# MoE configs end-to-end: dryrun compile cells + artifact roundtrip serving
# ---------------------------------------------------------------------------
import os
import subprocess
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape", [("grok_1_314b", "decode_32k"),
                                        ("llama4_scout_17b_a16e",
                                         "prefill_32k")])
def test_moe_dryrun_smoke_cell_compiles(arch, shape):
    """The MoE configs lower + compile through launch/dryrun.py (CI-shrunk
    dims, production 16x16 mesh of fake devices): sharding rules legal for
    stacked expert kernels, collectives supported — the configs execute,
    not just parse.  Subprocess: dryrun owns the 512-device XLA flag."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--smoke", "--no-save"],
        capture_output=True, text=True, timeout=560, env=env, cwd=_REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout[-3000:]}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "1/1 cells compiled OK" in out.stdout


@pytest.mark.parametrize("arch", ["grok_1_314b", "llama4_scout_17b_a16e"])
def test_moe_artifact_save_load_serve_roundtrip(arch, tmp_path):
    """quantize -> save -> load -> serve for the MoE archs: the stacked
    per-expert expansions (batch_dims=2 stage leaves) survive the disk
    roundtrip bit-exactly and the loaded artifact serves the same tokens."""
    import jax
    import numpy as np

    from repro.api import QuantArtifact, QuantRecipe, Runtime, quantize
    from repro.core.expansion import ExpandedTensor
    from repro.core.policy import W8A8
    from repro.infer.serve import ServeConfig
    from repro.models import model as M

    cfg = get_arch(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    art = quantize(params, QuantRecipe(policy=W8A8, arch=arch, smoke=True))
    assert art.expanded and art.meta["expansion_stats"]["expanded_leaves"] > 0
    art.save(str(tmp_path / arch))
    art2 = QuantArtifact.load(str(tmp_path / arch))

    # stacked expert leaves survive with their batch dims
    moe_leaf = art2.params["stages"]["b0_moe_attn"]["moe"]["wi"]["kernel"]
    assert isinstance(moe_leaf, ExpandedTensor)
    assert moe_leaf.batch_dims == 2          # (stages, experts)
    assert moe_leaf.planes.shape[1] == cfg.num_experts

    def serve(a):
        rt = Runtime(a, backend="ref", cfg=cfg)
        eng = rt.serve(ServeConfig(max_seq=48, max_batch=2, max_slots=2))
        rng = np.random.default_rng(3)
        for _ in range(3):
            eng.add_request(rng.integers(0, cfg.vocab_size, 6).tolist())
        return eng.run(max_new_tokens=4)

    out_mem, out_disk = serve(art), serve(art2)
    assert out_disk == out_mem, (out_disk, out_mem)
