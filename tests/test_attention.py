"""Flash (chunked online-softmax) attention vs naive reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as ATT


def naive_attention(q, k, v, *, causal=True, window=0, softcap=0.0):
    b, s, h, d = q.shape
    _, t, g, _ = k.shape
    r = h // g
    qg = q.reshape(b, s, g, r, d)
    sc = jnp.einsum("bsgrd,btgd->bgrst", qg, k) * (d ** -0.5)
    if softcap > 0:
        sc = softcap * jnp.tanh(sc / softcap)
    qp, kp = jnp.arange(s), jnp.arange(t)
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window > 0:
        mask &= kp[None, :] > qp[:, None] - window
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bgrst,btgd->bsgrd", p, v)
    return out.reshape(b, s, h, d)


def _qkv(rng, b=2, s=48, t=48, h=4, g=2, d=16):
    q = jnp.array(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.array(rng.normal(size=(b, t, g, d)).astype(np.float32))
    v = jnp.array(rng.normal(size=(b, t, g, d)).astype(np.float32))
    return q, k, v


@pytest.mark.parametrize("causal", (True, False))
@pytest.mark.parametrize("window", (0, 16))
@pytest.mark.parametrize("chunks", ((16, 16), (48, 48), (32, 16)))
def test_flash_matches_naive(rng, causal, window, chunks):
    q, k, v = _qkv(rng)
    qc, kc = chunks
    out = ATT.flash_attention(q, k, v, causal=causal, window=window,
                              q_chunk=qc, kv_chunk=kc)
    expect = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-4, atol=2e-4)


def test_flash_nondivisible_lengths(rng):
    """Padding path: s=50, t=37 with 16-chunks."""
    q, k, v = _qkv(rng, s=50, t=37)
    out = ATT.flash_attention(q, k, v, causal=False, q_chunk=16, kv_chunk=16)
    expect = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-4, atol=2e-4)


def test_softcap(rng):
    q, k, v = _qkv(rng)
    out = ATT.flash_attention(q, k, v, causal=True, softcap=5.0, q_chunk=16, kv_chunk=16)
    expect = naive_attention(q, k, v, causal=True, softcap=5.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-4, atol=2e-4)


def test_decode_matches_full(rng):
    """Single-token decode over a cache == last row of full attention."""
    b, s, h, g, d = 2, 33, 4, 2, 16
    q_full, k_full, v_full = _qkv(rng, b=b, s=s, t=s, h=h, g=g, d=d)
    full = naive_attention(q_full, k_full, v_full, causal=True)
    # cache with extra capacity
    pad = 7
    kc = jnp.pad(k_full, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v_full, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = ATT.decode_attention(q_full[:, -1:, :], kc, vc, jnp.int32(s))
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_decode_per_row_lengths(rng):
    b, s, h, g, d = 2, 16, 4, 2, 8
    q, k, v = _qkv(rng, b=b, s=1, t=s, h=h, g=g, d=d)
    lens = jnp.array([5, 12], jnp.int32)
    out = ATT.decode_attention(q, k, v, lens)
    for i, ln in enumerate([5, 12]):
        exp = ATT.decode_attention(q[i:i+1], k[i:i+1], v[i:i+1], jnp.int32(ln))
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(exp[0]), rtol=1e-5)
