"""Model-level PTQ driver: pytree walk, first/last 8-bit, size stats,
end-to-end output closeness, quant-time."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.core import expansion as E
from repro.core.expansion import ExpandedTensor
from repro.core.policy import ExpansionPolicy, W2A2, W4A4, W8A8
from repro.core.ptq import (expand_params, expand_params_timed, expansion_stats,
                            max_weight_residual)
from repro.models import model as M
from repro.models.layers import QuantContext


def _tiny_params(rng):
    r = np.random.default_rng(0)
    return {
        "embed": {"embedding": jnp.array(r.normal(size=(64, 16)).astype(np.float32))},
        "stages": {"b0_attn": {"attn": {"q": {"kernel": jnp.array(r.normal(size=(2, 16, 16)).astype(np.float32))}},
                               "ln": {"scale": jnp.ones((2, 16))}}},
        "lm_head": {"kernel": jnp.array(r.normal(size=(16, 64)).astype(np.float32))},
    }


def test_walk_selects_gemm_weights(rng):
    q = expand_params(_tiny_params(rng), W4A4)
    assert isinstance(q["stages"]["b0_attn"]["attn"]["q"]["kernel"], ExpandedTensor)
    assert isinstance(q["lm_head"]["kernel"], ExpandedTensor)
    # embedding gather table & norms stay FP
    assert not isinstance(q["embed"]["embedding"], ExpandedTensor)
    assert not isinstance(q["stages"]["b0_attn"]["ln"]["scale"], ExpandedTensor)


def test_first_last_8bit(rng):
    q = expand_params(_tiny_params(rng), W4A4)
    assert q["lm_head"]["kernel"].bits == 8       # last layer protected (§5.1)
    assert q["stages"]["b0_attn"]["attn"]["q"]["kernel"].bits == 4


def test_stacked_stage_weights_get_batch_dims(rng):
    q = expand_params(_tiny_params(rng), W4A4)
    et = q["stages"]["b0_attn"]["attn"]["q"]["kernel"]
    assert et.batch_dims == 1                      # per-layer quantizers
    assert et.planes.shape[0] == 2


def test_mixed_precision_override(rng):
    pol = ExpansionPolicy(w_bits=4, a_bits=4, mixed=(("lm_head", (2, 8)),),
                          first_last_bits=4)
    q = expand_params(_tiny_params(rng), pol)
    assert q["lm_head"]["kernel"].bits == 2


def test_expansion_stats(rng):
    q = expand_params(_tiny_params(rng), W4A4)
    st = expansion_stats(q)
    assert st["expanded_leaves"] == 2
    assert st["compression"] > 1.0                 # W4 planes beat fp32 storage


def test_max_weight_residual_threshold(rng):
    p = _tiny_params(rng)
    res = []
    for terms in (1, 2, 3):
        pol = ExpansionPolicy(w_bits=4, a_bits=4, w_terms=terms, first_last_terms=terms)
        res.append(float(max_weight_residual(p, expand_params(p, pol))))
    assert res[0] > res[1] > res[2]


@pytest.mark.parametrize("pol,tol", [(W8A8, 0.05), (W4A4, 0.15)])
def test_e2e_model_output_close(rng, pol, tol):
    """Quantized smoke model's logits stay close to FP — the PTQ contract."""
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.array(rng.integers(0, cfg.vocab_size, (2, 24)), jnp.int32)
    y_fp = M.forward(params, {"tokens": tokens}, cfg)
    q = expand_params(params, pol)
    y_q = M.forward(q, {"tokens": tokens}, cfg, QuantContext(policy=pol))
    rel = float(jnp.linalg.norm(y_q - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < tol, rel
    # top-1 predictions mostly preserved
    agree = float(jnp.mean((jnp.argmax(y_q, -1) == jnp.argmax(y_fp, -1)).astype(jnp.float32)))
    assert agree > 0.8, agree


def test_quant_time_is_fast(rng):
    """Calibration-free expansion is seconds, not hours (paper Table 3)."""
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    _, seconds = expand_params_timed(params, W4A4)
    assert seconds < 60.0


def test_expand_is_deterministic(rng):
    p = _tiny_params(rng)
    q1 = expand_params(p, W4A4)
    q2 = expand_params(p, W4A4)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), q1, q2)


def test_percentile_observer_streams_instead_of_ratcheting():
    """Regression: PercentileObserver took a running MAX of per-batch
    percentiles, which converges to the global absmax over many calibration
    batches (any batch whose percentile lands near an outlier ratchets the
    estimate up for good) — defeating the outlier-robustness it documents.
    The streaming mean of batch percentiles must stay near the typical
    percentile, far below the global absmax."""
    from repro.quant.observers import PercentileObserver

    obs = PercentileObserver(p=99.0)
    r = np.random.default_rng(0)
    global_absmax = 0.0
    for i in range(50):
        x = r.normal(size=4096).astype(np.float32)
        x[0] = 100.0 + i          # one huge outlier per calibration batch
        global_absmax = max(global_absmax, float(np.abs(x).max()))
        obs.update(jnp.asarray(x))
    lo, hi = obs.range()
    assert float(lo) == -float(hi)
    # typical 99th percentile of N(0,1) is ~2.6; the outliers put the global
    # absmax at ~149 — a running max would have converged toward it
    assert 1.5 < float(hi) < 10.0, float(hi)
    assert float(hi) < 0.1 * global_absmax
