"""Quantized MoE serving end-to-end (DESIGN.md §15), fake devices via
subprocess — the main pytest process must keep 1 device, per the dry-run
isolation contract (same pattern as test_dist_serving.py):

* ``placement="expert"`` (stacked per-expert expansions sharded over an
  "expert" mesh axis, grouped series GEMM + one int32 psum) serves the
  slot-scheduler continuous-batching workload TOKEN-IDENTICAL to the
  replicated oracle on 1/2/4 fake devices — through mixed lengths, slot
  recycling, per-request budgets, QoS quality tiers and self-speculative
  decode — for both MoE arch flavors (grok: top-2 + softcaps; llama4:
  top-1 + shared expert);
* the integer-psum contract holds on the ``"expert"`` axis
  (``check_integer_psum(axes=("expert",))``) and the 2-D
  ``("expert", "expand")`` composition serves token-identically too;
* the grouped dispatch is O(terms), not O(E·terms): the expert-GEMM
  ``dot_general`` census is independent of E (in-process — tracing only);
* the slot scheduler reports per-round expert-load imbalance
  (``last_run_stats["moe"]``) with one end-of-run host transfer.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(*parts: str, n_devices: int = 4, timeout=560):
    """Run the dedented concatenation of ``parts`` in a fake-device
    subprocess; the combined script must end by printing OK."""
    py_src = "\n".join(textwrap.dedent(p) for p in parts)
    assert "OK" in py_src.rsplit("print", 1)[-1], "test body must print ...OK"
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["REPRO_NO_PALLAS"] = "1"   # sharded placements serve the ref path
    out = subprocess.run([sys.executable, "-c", py_src],
                         capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "OK" in out.stdout, f"script did not reach its OK print:\n{out.stdout}"
    return out.stdout


_COMMON = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import QuantRecipe, Runtime, quantize
    from repro.configs.base import get_arch
    from repro.core.policy import W4A4, W4A16, W8A8
    from repro.dist.expert_parallel import make_moe_mesh
    from repro.dist.placement import make_serve_mesh
    from repro.infer.serve import ServeConfig
    from repro.models import model as M

    def build(arch, policy, placement, mesh=None, cfg=None, art=None):
        cfg = cfg or get_arch(arch, smoke=True)
        if art is None:
            params = M.init_params(jax.random.PRNGKey(0), cfg)
            art = quantize(params, QuantRecipe(policy=policy, arch=arch,
                                               smoke=True))
        rt = Runtime(art, backend="ref", cfg=cfg, mesh=mesh,
                     placement=placement)
        return cfg, art, rt

    def serve_workload(rt, cfg, *, n_req=6, slots=2, max_seq=48, seed=1,
                       sc=None, qualities=None):
        # mixed lengths + per-request budgets + recycling (n_req > slots)
        eng = rt.serve(sc or ServeConfig(max_seq=max_seq, max_batch=slots,
                                         max_slots=slots))
        rng = np.random.default_rng(seed)
        for i in range(n_req):
            L = int(rng.integers(4, 14))
            kw = {}
            if qualities:
                kw["quality"] = qualities[i % len(qualities)]
            eng.add_request(rng.integers(0, cfg.vocab_size, L).tolist(),
                            max_new_tokens=int(rng.integers(3, 7)), **kw)
        out = eng.run(max_new_tokens=6)
        return out, eng.last_run_stats
"""


@pytest.mark.parametrize("n_devices", [1, 2, 4])
def test_expert_parallel_token_identical_grok(n_devices):
    """grok flavor (top-2, softcaps, E=4 smoke) on a 1/2/4-device expert
    mesh: generated tokens identical to the replicated oracle through slot
    recycling; the scheduler reports expert-load telemetry with zero drops
    (the serving routing rule is dropless by construction)."""
    _run(_COMMON, f"""
        n = {n_devices}
        arch = "grok_1_314b"
        cfg, art, rt_rep = build(arch, W4A4, "replicated")
        mesh = make_moe_mesh(n)
        _, _, rt_ep = build(arch, W4A4, "expert", mesh, cfg=cfg, art=art)

        out_rep, st_rep = serve_workload(rt_rep, cfg)
        out_ep, st_ep = serve_workload(rt_ep, cfg)
        assert out_ep == out_rep, (out_ep, out_rep)
        assert st_ep["placement"] == "expert"
        assert st_ep["mesh_devices"] == n
        for st in (st_rep, st_ep):
            moe = st["moe"]
            assert len(moe["tokens_per_expert"]) == cfg.num_experts
            assert moe["dispatches"] > 0
            assert moe["drop_fraction"] == 0.0
            assert moe["imbalance"] >= 1.0
        assert st_ep["moe"] == st_rep["moe"]   # telemetry is placement-blind
        print("expert-parallel grok OK")
    """, n_devices=n_devices)


def test_expert_parallel_token_identical_llama4_shared():
    """llama4 flavor (top-1 + shared expert, E=4 smoke) on 4 devices: the
    dense shared-expert branch runs replicated next to the sharded routed
    experts and the stream stays token-identical; weight-only policies take
    the FP-dequant expert path (the waivered psum) and match too."""
    _run(_COMMON, """
        arch = "llama4_scout_17b_a16e"
        mesh = make_moe_mesh(4)
        for policy in (W4A4, W4A16):
            cfg, art, rt_rep = build(arch, policy, "replicated")
            _, _, rt_ep = build(arch, policy, "expert", mesh, cfg=cfg,
                                art=art)
            out_rep, _ = serve_workload(rt_rep, cfg)
            out_ep, _ = serve_workload(rt_ep, cfg)
            assert out_ep == out_rep, (policy, out_ep, out_rep)
        print("expert-parallel llama4 OK")
    """)


def test_expert_parallel_qos_tiers_token_identical():
    """QoS quality tiers (per-request term budgets -> masked per-tier
    dispatch groups) on the expert placement: the term budget masks
    trailing scales inside the grouped GEMM, and every tier's stream is
    token-identical to the replicated engine serving the same ladder."""
    _run(_COMMON, """
        arch = "grok_1_314b"
        cfg, art, rt_rep = build(arch, W4A4, "replicated")
        mesh = make_moe_mesh(2)
        _, _, rt_ep = build(arch, W4A4, "expert", mesh, cfg=cfg, art=art)

        sc = ServeConfig(max_seq=48, max_batch=2, max_slots=2,
                         tier_budgets=(("k1", 1),))
        out_rep, _ = serve_workload(rt_rep, cfg, sc=sc,
                                    qualities=("full", "k1"))
        sc2 = ServeConfig(max_seq=48, max_batch=2, max_slots=2,
                          tier_budgets=(("k1", 1),))
        out_ep, st = serve_workload(rt_ep, cfg, sc=sc2,
                                    qualities=("full", "k1"))
        assert out_ep == out_rep, (out_ep, out_rep)
        assert st["tiers"]["k1"]["served_tokens"] > 0
        assert st["tiers"]["k1"]["mean_effective_terms"] == 1.0
        print("expert-parallel QoS tiers OK")
    """, n_devices=2)


def test_expert_parallel_spec_decode_token_identical():
    """Self-speculative decode (k-term draft + full-series verify) over the
    expert placement: greedy output must stay token-identical to both the
    replicated speculative engine and the non-speculative oracle."""
    _run(_COMMON, """
        arch = "grok_1_314b"
        cfg, art, rt_rep = build(arch, W4A4, "replicated")
        mesh = make_moe_mesh(2)
        _, _, rt_ep = build(arch, W4A4, "expert", mesh, cfg=cfg, art=art)

        plain = ServeConfig(max_seq=48, max_batch=2, max_slots=2)
        spec = ServeConfig(max_seq=48, max_batch=2, max_slots=2,
                           spec_terms=1, spec_lookahead=2)
        out_oracle, _ = serve_workload(rt_rep, cfg, sc=plain)
        out_rep, _ = serve_workload(rt_rep, cfg, sc=spec)
        out_ep, st = serve_workload(rt_ep, cfg, sc=spec)
        assert out_rep == out_oracle, (out_rep, out_oracle)
        assert out_ep == out_rep, (out_ep, out_rep)
        assert st["spec_rounds"] > 0
        print("expert-parallel spec decode OK")
    """, n_devices=2)


def test_expert_axis_integer_psum_and_2d_mesh():
    """The Abelian contract on the second mesh axis: ``check_integer_psum``
    passes on ``axes=("expert",)`` for the series path, and the 2-D
    ``("expert", "expand")`` composition (experts sharded AND dense terms
    scattered) serves token-identically to the replicated oracle."""
    _run(_COMMON, """
        from repro.analysis.jaxpr_check import check_integer_psum
        from repro.core.policy import W4A4 as POL
        from repro.dist.expert_parallel import grouped_parallel_apply

        mesh1 = make_moe_mesh(2)
        cfg, art, rt_rep = build("grok_1_314b", W4A4, "replicated")
        w_et = rt_rep.params["stages"]["b0_moe_attn"]["moe"]["wi"]["kernel"]
        # the stage-stacked leaf is (L, E, ...); take stage 0 -> (E, ...)
        import dataclasses as dc
        if w_et.batch_dims == 2:
            w_et = dc.replace(
                w_et,
                planes=w_et.planes[0], scales=w_et.scales[0],
                bias=None if w_et.bias is None else w_et.bias[0],
                sat=None if w_et.sat is None else w_et.sat[0],
                batch_dims=1)
        x = jnp.ones((cfg.num_experts, 3, cfg.d_model), jnp.float32)
        check_integer_psum(
            lambda xx: grouped_parallel_apply(xx, w_et, POL, mesh1),
            x, axes=("expert",), strict=True)
        print("integer psum on expert axis OK")

        mesh2 = make_moe_mesh(2, 2)        # 2 experts x 2 term shards
        assert dict(mesh2.shape) == {"expert": 2, "expand": 2}
        _, _, rt_2d = build("grok_1_314b", W4A4, "expert", mesh2, cfg=cfg,
                            art=art)
        assert rt_2d.qc.term_parallel and rt_2d.qc.expert_parallel
        out_rep, _ = serve_workload(rt_rep, cfg)
        out_2d, st = serve_workload(rt_2d, cfg)
        assert out_2d == out_rep, (out_2d, out_rep)
        assert st["mesh_devices"] == 4
        print("2-D expert x term mesh OK")
    """)


def test_grouped_dispatch_census_independent_of_expert_count():
    """O(terms), not O(E·terms): the dot_general census of the MoE FFN is
    identical for E=4 and E=8 — the grouped series GEMM batches the expert
    axis inside each dispatch (tracing only; no fake devices needed)."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_check import dispatch_census
    from repro.configs.base import get_arch
    from repro.core.policy import W8A8
    from repro.core.ptq import expand_params
    from repro.models import moe as MOE
    from repro.models.layers import QuantContext

    counts = {}
    for e in (4, 8):
        cfg = dataclasses.replace(get_arch("grok_1_314b", smoke=True),
                                  num_experts=e)
        params = expand_params(MOE.moe_init(jax.random.PRNGKey(0), cfg),
                               W8A8)
        qc = QuantContext(policy=W8A8, moe_routing="token")
        x = jnp.ones((2, 1, cfg.d_model), jnp.float32)
        counts[e] = dispatch_census(
            lambda p, xx: MOE.moe_apply(qc, p, xx, cfg), params, x)
    assert counts[4]["dot_general"] == counts[8]["dot_general"], counts
    assert counts[4]["dot_general"] > 0


def test_moe_stats_channel_single_device():
    """last_run_stats["moe"]: per-round expert-load imbalance telemetry on
    a plain single-device slots run — load vector length E, max/mean per
    round coherent, dropless under the serving routing rule."""
    import jax
    import numpy as np

    from repro.configs.base import get_arch
    from repro.core.policy import W8A8
    from repro.infer.serve import Engine, ServeConfig
    from repro.models import model as M

    cfg = get_arch("llama4_scout_17b_a16e", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, policy=W8A8,
                 serve_cfg=ServeConfig(max_seq=48, max_batch=2, max_slots=2))
    rng = np.random.default_rng(0)
    for _ in range(4):
        eng.add_request(rng.integers(0, cfg.vocab_size, 6).tolist(),
                        max_new_tokens=4)
    out = eng.run(max_new_tokens=4)
    assert len(out) == 4
    moe = eng.last_run_stats["moe"]
    assert len(moe["tokens_per_expert"]) == cfg.num_experts
    assert moe["dispatches"] > 0
    assert sum(moe["tokens_per_expert"]) > 0
    assert moe["max_tokens_per_expert"] >= moe["mean_tokens_per_expert"] > 0
    assert moe["imbalance"] >= 1.0
    assert moe["drop_fraction"] == 0.0
