"""Training substrate: convergence, grad-accum equivalence, optimizers,
gradient compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.dist.compression import CompressionConfig, compress_decompress, make_compressor, wire_bytes
from repro.models import model as M
from repro.train.data import SyntheticLM, make_batch, make_host_loader
from repro.train.optimizer import adafactor, adamw, sgd, clip_by_global_norm, global_norm, warmup_cosine
from repro.train.train_step import TrainConfig, make_train_step


def test_loss_decreases_on_markov_data():
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    opt, step = make_train_step(cfg, TrainConfig(lr=3e-3, remat=False))
    opt_state = opt.init(params)
    step = jax.jit(step)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 64, 8, i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[:3] + losses[-3:]


def test_grad_accum_equivalence():
    """grad_accum=4 == single big batch (same grads up to fp noise)."""
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 32, 8, 0).items()}

    outs = []
    for ga in (1, 4):
        opt, step = make_train_step(cfg, TrainConfig(lr=1e-2, grad_accum=ga,
                                                     remat=False, grad_clip=0.0))
        p2, _, m = jax.jit(step)(params, opt.init(params), batch)
        outs.append((p2, float(m["loss"])))
    # same loss (mean over microbatches == full-batch mean for equal sizes)
    np.testing.assert_allclose(outs[0][1], outs[1][1], rtol=1e-5)
    flat0 = jax.tree_util.tree_leaves(outs[0][0])
    flat1 = jax.tree_util.tree_leaves(outs[1][0])
    for a, b in zip(flat0, flat1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)


# note: adam-family steps are lr-normalized (~lr per step), so the quadratic
# needs lr ~ 0.1 to traverse O(3) distance in 60 steps; sgd steps scale with
# the gradient and converge at lr 1e-2
@pytest.mark.parametrize("make_opt", [lambda: adamw(lr=0.1),
                                      lambda: adamw(lr=0.1, moment_dtype=jnp.bfloat16),
                                      lambda: adafactor(lr=0.1),
                                      lambda: sgd(lr=1e-2)])
def test_optimizers_reduce_quadratic(make_opt):
    """Every optimizer minimizes a simple quadratic."""
    opt = make_opt()
    params = {"w": jnp.array([3.0, -2.0, 1.5]), "b": {"kernel": jnp.ones((4, 2)) * 2}}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"]["kernel"] ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, params, state)
    assert float(loss(params)) < 0.2 * l0


def test_clip_and_norm():
    g = {"a": jnp.ones((10,)) * 3.0}
    assert abs(float(global_norm(g)) - 3.0 * np.sqrt(10)) < 1e-5
    gc = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(gc)) - 1.0) < 1e-5
    # under the clip threshold: unchanged
    gs = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(gs["a"]), np.asarray(g["a"]))


def test_warmup_cosine_schedule():
    s = warmup_cosine(10, 100)
    assert float(s(jnp.int32(5))) == pytest.approx(0.5)
    assert float(s(jnp.int32(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(s(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)


# ---------------------------------------------------------------------------
# gradient compression (paper codec reused, beyond-paper)
# ---------------------------------------------------------------------------
def test_compress_decompress_bound(rng):
    g = jnp.array(rng.normal(size=(128, 64)).astype(np.float32))
    cc = CompressionConfig(bits=8, terms=1, min_size=1)
    dec = compress_decompress(g, cc)
    rel = float(jnp.linalg.norm(dec - g) / jnp.linalg.norm(g))
    assert rel < 0.01
    cc2 = CompressionConfig(bits=8, terms=2, min_size=1)
    dec2 = compress_decompress(g, cc2)
    assert float(jnp.linalg.norm(dec2 - g)) < float(jnp.linalg.norm(dec - g))


def test_error_feedback_accumulates_to_truth(rng):
    """EF: sum of decoded grads over steps converges to sum of true grads."""
    cc = CompressionConfig(bits=2, terms=1, min_size=1)  # aggressive 2-bit
    g_true = jnp.array(rng.normal(size=(64, 32)).astype(np.float32))
    params_like = {"w": g_true}
    init_err, compress = make_compressor(params_like, cc)
    err = init_err()
    acc = jnp.zeros_like(g_true)
    acc_no_ef = jnp.zeros_like(g_true)
    n = 30
    for _ in range(n):
        dec, err = compress({"w": g_true}, err)
        acc = acc + dec["w"]
        acc_no_ef = acc_no_ef + compress_decompress(g_true, cc)
    rel = float(jnp.linalg.norm(acc / n - g_true) / jnp.linalg.norm(g_true))
    rel_no_ef = float(jnp.linalg.norm(acc_no_ef / n - g_true) / jnp.linalg.norm(g_true))
    assert rel < 0.10, rel                  # EF time-average approaches truth
    assert rel < 0.5 * rel_no_ef, (rel, rel_no_ef)  # and beats no-EF clearly


def test_wire_bytes_accounting():
    params = {"w": jnp.zeros((1024, 1024)), "tiny": jnp.zeros((8,))}
    fp, comp = wire_bytes(params, CompressionConfig(bits=8, terms=1))
    assert fp == 1024 * 1024 * 4 + 32
    assert comp < fp / 3.5  # ~4x for the large leaf


def test_compressed_training_still_converges():
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    cc = CompressionConfig(bits=8, terms=1, min_size=256)
    init_err, compress = make_compressor(jax.eval_shape(lambda: params), cc)
    holder = {"err": init_err()}

    def compressor(grads):
        dec, holder["err"] = compress(grads, holder["err"])
        return dec

    opt, step = make_train_step(cfg, TrainConfig(lr=3e-3, remat=False),
                                compressor=compressor)
    opt_state = opt.init(params)
    losses = []
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 64, 8, i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-4:]) < np.mean(losses[:4]) - 0.2
