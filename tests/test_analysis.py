"""The static-analysis subsystem (repro.analysis, DESIGN.md §12).

Two halves, both mandatory:

* **mutation self-tests** — seed each historical bug class and assert the
  owning checker FIRES with a pointed diagnostic (a checker that cannot
  fail is not a check): f32 psum on the expand axis, a second host
  transfer per decode round, a dynamic operand marked static, a duplicated
  grid-constant table, a bare runtime assert, a donated buffer reused;
* **clean-pass + serving regressions** — the unmutated tree passes every
  checker with zero violations, and live engine runs (plain, speculative,
  QoS-masked) honor the one-transfer-per-round contract and the pinned
  jit-cache sizes.
"""
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.analysis as A
from repro.analysis import budgets as AB
from repro.analysis.jaxpr_check import check_budget, check_no_retrace
from repro.analysis.lint import lint_file, run_lint
from repro.configs.base import get_arch
from repro.core.policy import ExpansionPolicy
from repro.infer.serve import Engine, ServeConfig
from repro.models import model as M

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src", "repro")

W4A16_T3 = ExpansionPolicy(w_bits=4, a_bits=16, w_terms=3, a_terms=0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, lengths, seed=0):
    r = np.random.default_rng(seed)
    return [r.integers(0, cfg.vocab_size, l).tolist() for l in lengths]


def _mesh():
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]), ("expand",))


# ===========================================================================
# mutation self-tests: seed the bug, the checker must fire with file:line
# ===========================================================================
def test_mutation_float_psum_fires():
    """An f32 psum on the expand axis (the PR 4 divergence class) is caught,
    with the psum's source site in the diagnostic."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = _mesh()

    def bad(x):
        return shard_map(lambda v: jax.lax.psum(v, "expand"),
                         mesh=mesh, in_specs=P(), out_specs=P())(x)

    with pytest.raises(A.AnalysisViolation) as exc:
        A.check_integer_psum(bad, jnp.ones((4,), jnp.float32))
    msg = str(exc.value)
    assert "integer-psum" in msg and "float32" in msg
    assert "test_analysis.py" in msg  # pointed: names THIS file's psum


def test_mutation_int_psum_passes():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = _mesh()

    def good(x):
        return shard_map(lambda v: jax.lax.psum(v, "expand"),
                         mesh=mesh, in_specs=P(), out_specs=P())(x)

    assert A.check_integer_psum(good, jnp.ones((4,), jnp.int32)) == []


def test_mutation_waiver_reports_without_raising():
    """The weight-only float psum is reported (never silently dropped) but
    does not fail when run non-strict under a declared waiver."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = _mesh()

    def weight_only(x):
        return shard_map(lambda v: jax.lax.psum(v, "expand"),
                         mesh=mesh, in_specs=P(), out_specs=P())(x)

    found = A.check_integer_psum(weight_only, jnp.ones((4,), jnp.float32),
                                 strict=False)
    assert len(found) == 1 and found[0].rule == "integer-psum"


def test_mutation_host_callback_counted():
    def with_cb(x):
        return jax.pure_callback(
            lambda a: np.asarray(a),
            jax.ShapeDtypeStruct((4,), jnp.float32), x)

    assert A.count_host_callbacks(with_cb, jnp.ones(4)) == 1
    assert A.count_host_callbacks(lambda x: x * 2, jnp.ones(4)) == 0


def test_mutation_double_transfer_fires():
    """A second device_get inside a decode round (the PR 5 drain-miscount
    class) breaches the census, and the diagnostic carries the call sites."""
    census = A.TransferCensus()
    step = census.wrap_dispatch(lambda x: x + 1)
    with census:
        x = jnp.ones(2)
        for _ in range(3):
            x = step(x)
            jax.device_get(x)          # the contracted transfer
            jax.device_get(x)          # the seeded bug: one too many
    with pytest.raises(A.AnalysisViolation) as exc:
        census.check(max_per_round=1)
    msg = str(exc.value)
    assert "transfer-census" in msg and "test_analysis.py" in msg
    assert census.rounds == 3 and census.transfers == 6


def test_mutation_transfer_census_clean():
    census = A.TransferCensus()
    step = census.wrap_dispatch(lambda x: x + 1)
    with census:
        x = jnp.ones(2)
        for _ in range(3):
            x = step(x)
            jax.device_get(x)
    assert census.check(max_per_round=1) == []


def test_mutation_static_temperature_retraces():
    """temperature marked static (the PR 3 class): two distinct values mean
    two traces, and the tripwire fires; passed dynamically, one trace."""
    @jax.jit
    def dynamic(x, temperature):
        return x / jnp.maximum(temperature, 1e-6)

    from functools import partial

    @partial(jax.jit, static_argnames=("temperature",))
    def static(x, temperature):
        return x / max(temperature, 1e-6)

    x = jnp.ones(4)
    for t in (0.5, 0.9):
        dynamic(x, jnp.asarray(t))
        static(x, t)
    assert A.jit_cache_sizes({"dynamic": dynamic})["dynamic"] == 1
    with pytest.raises(A.AnalysisViolation) as exc:
        check_no_retrace({"static": static})
    assert "retrace" in str(exc.value) and "2 traces" in str(exc.value)


def test_mutation_donation_double_apply_fires(setup):
    """Re-dispatching with an already-donated cache tree (the chaos
    double-apply class) raises even on CPU, where jax silently ignores
    donation and the bug would otherwise pass every test."""
    cfg, params = setup
    ledger = A.DonationLedger()
    step = ledger.wrap(lambda p, tok, caches: (tok + 1, caches),
                       donate_argnums=(2,))
    caches = {"k": jnp.zeros((2, 4)), "v": jnp.zeros((2, 4))}
    step(params, jnp.ones((2, 1), jnp.int32), caches)     # donates caches
    with pytest.raises(A.AnalysisViolation) as exc:
        step(params, jnp.ones((2, 1), jnp.int32), caches)  # double-apply
    assert "donation-reuse" in str(exc.value)
    assert "test_analysis.py" in str(exc.value)  # where it was donated


def test_mutation_donation_failed_dispatch_not_spent():
    """A dispatch that RAISES never consumed its donated buffers — the
    chaos-retry contract: retry with the same buffers must be legal."""
    ledger = A.DonationLedger()
    calls = {"n": 0}

    def flaky(caches):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("chaos: injected transient failure")
        return caches["k"] + 1

    step = ledger.wrap(flaky, donate_argnums=(0,))
    caches = {"k": jnp.zeros(3)}
    with pytest.raises(RuntimeError):
        step(caches)
    step(caches)                        # the retry — must NOT trip the ledger
    with pytest.raises(A.AnalysisViolation):
        step(caches)                    # but a third use does


def test_mutation_budget_breach_fires():
    measured = {"dot_general": 40, "callbacks": 1}
    budget = {"dot_general": 17, "callbacks": 0}
    with pytest.raises(A.AnalysisViolation) as exc:
        check_budget(measured, budget, entry="decode")
    msg = str(exc.value)
    assert "dispatch-budget" in msg and "analysis_budgets.json:decode" in msg
    assert "40" in msg and "17" in msg


# ---------------------------------------------------------------------------
# lint mutations (REPRO101-104): each rule fires on seeded source
# ---------------------------------------------------------------------------
def _write(tmp_path, rel, source):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return str(p)


def test_lint_bare_assert_fires(tmp_path):
    p = _write(tmp_path, "repro/infer/mutated.py", """
        def admit(n):
            assert n > 0, "no slots"
            return n
    """)
    errs = lint_file(p)
    assert len(errs) == 1 and errs[0].rule == "REPRO101"
    assert f"{p}:3:" in str(errs[0])          # file:line:col prefix


def test_lint_bare_assert_ignores_kernels_and_tests(tmp_path):
    for rel in ("repro/kernels/k.py", "repro/core/c.py", "tests/test_x.py"):
        p = _write(tmp_path, rel, "def f(n):\n    assert n\n    return n\n")
        assert lint_file(p) == [], rel


def test_lint_static_dynamic_operand_fires(tmp_path):
    p = _write(tmp_path, "repro/infer/mutated.py", """
        import jax
        step = jax.jit(lambda x, temperature: x, static_argnames=("temperature",))
    """)
    errs = lint_file(p)
    assert len(errs) == 1 and errs[0].rule == "REPRO102"
    assert "temperature" in errs[0].message


def test_lint_duplicate_plane_limits_fires(tmp_path):
    p = _write(tmp_path, "repro/somewhere/dup.py", """
        def _plane_limits(bits, k, pack_safe=False):
            hi = 2 ** (bits - 1) - 1
            return -hi, hi
    """)
    errs = lint_file(p)
    assert len(errs) == 1 and errs[0].rule == "REPRO103"
    assert "numerics" in errs[0].message


def test_lint_duplicate_function_body_fires(tmp_path):
    body = """
        def lookup_table(x):
            table = {1: 7, 2: 127, 3: 255}
            return table[x]
    """
    _write(tmp_path, "repro/a/mod_a.py", body)
    _write(tmp_path, "repro/b/mod_b.py", body)
    errs = run_lint([str(tmp_path)])
    dup = [e for e in errs if e.rule == "REPRO103"]
    assert len(dup) == 1
    # the finding points at one copy and names the other (walk order decides
    # which is which)
    assert "duplicates" in dup[0].message
    combined = dup[0].path + " " + dup[0].message
    assert "mod_a.py" in combined and "mod_b.py" in combined


def test_lint_jit_in_loop_fires(tmp_path):
    p = _write(tmp_path, "repro/infer/mutated.py", """
        import jax
        def serve(xs):
            out = []
            for x in xs:
                out.append(jax.jit(lambda v: v + 1)(x))
            return out
    """)
    errs = lint_file(p)
    assert len(errs) == 1 and errs[0].rule == "REPRO104"


# ===========================================================================
# clean pass: the unmutated tree has zero violations
# ===========================================================================
def test_src_tree_lints_clean():
    errs = run_lint([SRC])
    assert errs == [], "\n".join(str(e) for e in errs)


def test_committed_budget_ledger_holds():
    """Measured dispatch censuses stay within the committed ceilings, and
    the ledger covers every contracted budget_key."""
    ledger = AB.load_budgets()
    assert set(ledger) == {"decode", "decode_masked", "spec_decode",
                           "spec_decode_masked", "prefill", "decode_paged",
                           "spec_decode_paged", "spec_decode_paged_masked",
                           "prefill_chunk", "prefill_chunk_paged",
                           "decode_moe"}
    assert AB.check_budgets(strict=False) == []


def test_fused_decode_has_no_host_callbacks(setup):
    """The fused decode step compiles zero host round-trips in-graph."""
    cfg, params = setup
    steps = AB._fixture_steps()
    for entry in ("decode", "decode_masked", "spec_decode", "decode_paged",
                  "spec_decode_paged", "prefill_chunk",
                  "prefill_chunk_paged"):
        fn, args = steps[entry]
        assert A.count_host_callbacks(fn, *args) == 0, entry


def test_contracts_declared_on_entry_points(setup):
    cfg, _ = setup
    from repro.infer.serve import make_decode_sample_step, make_spec_decode_step
    from repro.models.layers import FP
    for fn, name in [
        (make_decode_sample_step(cfg, FP, masked=False), "fused_decode"),
        (make_decode_sample_step(cfg, FP, masked=True), "fused_decode_masked"),
        (make_spec_decode_step(cfg, FP, FP, 2), "spec_decode"),
    ]:
        c = A.get_contract(fn)
        assert c is not None and c.name == name
        assert c.transfers_per_round == 1
        # both integer-psum contracts are policed on every serving entry
        # point: "expand" (term placement, §9) and "expert" (MoE expert
        # placement, §15) — policing an absent mesh axis is a no-op
        assert c.int_psum_axes == ("expand", "expert")


def test_placement_psum_axes():
    from repro.dist.placement import int_psum_axes
    assert int_psum_axes("term") == ("expand",)
    assert int_psum_axes("tensor") == ()
    assert int_psum_axes("replicated") == ()
    assert int_psum_axes("expert") == ("expert", "expand")


def test_hlo_collective_census_cross_check():
    """The HLO-side twin of the psum rule sees what XLA lowered."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.hlo_cost import check_integer_collectives

    mesh = _mesh()

    def f(x):
        return shard_map(lambda v: jax.lax.psum(v, "expand"),
                         mesh=mesh, in_specs=P(), out_specs=P())(x)

    bad = jax.jit(f).lower(jnp.ones((4,), jnp.float32)).compile().as_text()
    good = jax.jit(f).lower(jnp.ones((4,), jnp.int32)).compile().as_text()
    assert check_integer_collectives(bad), "f32 all-reduce must be flagged"
    assert check_integer_collectives(good) == []


# ===========================================================================
# serving regressions: live engines honor the transfer + retrace contracts
# ===========================================================================
def _run_censused(eng, prompts, *, max_new_tokens, qualities=None):
    """Run an engine under a TransferCensus with its dispatches marked as
    round boundaries; returns (outputs, census)."""
    census = A.TransferCensus()
    # _decode_for is the scheduler's per-tier dispatch lookup — wrapping it
    # marks EVERY fused dispatch (any budget) as a round boundary, without
    # touching the cached jits the retrace tripwire inspects
    orig_decode_for = eng._decode_for
    eng._decode_for = lambda b: census.wrap_dispatch(
        orig_decode_for(b), f"decode[k={b}]")
    if eng._spec is not None:
        eng._spec = census.wrap_dispatch(eng._spec, "spec")
    if getattr(eng, "chunked", False):
        orig_chunk_for = eng._chunk_for
        eng._chunk_for = lambda b: census.wrap_dispatch(
            orig_chunk_for(b), f"chunk[k={b}]")
    ids = []
    for i, p in enumerate(prompts):
        q = qualities[i % len(qualities)] if qualities else "full"
        ids.append(eng.add_request(p, quality=q))
    with census:
        out = eng.run(max_new_tokens=max_new_tokens)
    return out, census


def test_transfer_census_plain_slots(setup):
    """Plain slots engine: exactly one host transfer per decode round."""
    cfg, params = setup
    eng = Engine(cfg, params, serve_cfg=ServeConfig(max_seq=48, max_batch=4))
    out, census = _run_censused(eng, _prompts(cfg, [8, 8, 8]),
                                max_new_tokens=5)
    assert census.rounds > 0
    assert census.check(max_per_round=1) == []
    assert all(len(v) == 5 for v in out.values())


def test_transfer_census_chunked_prefill(setup):
    """Chunked-prefill engine: one host transfer per fused chunk round —
    splicing live decode rows into the chunk dispatch must not add a second
    per-round transfer (DESIGN.md §14)."""
    cfg, params = setup
    eng = Engine(cfg, params, serve_cfg=ServeConfig(
        max_seq=48, max_slots=3, prefill_chunk=8))
    out, census = _run_censused(eng, _prompts(cfg, [19, 8, 12, 21]),
                                max_new_tokens=5)
    assert census.rounds > 0
    assert census.check(max_per_round=1) == []
    assert all(len(v) == 5 for v in out.values())


def test_transfer_census_prefix_cached(setup):
    """Paged prefix-cache engine: shared-prefix admission (trie walk,
    increfs, recompute-row planning) stays host-side — the fused rounds
    still issue exactly one transfer each."""
    cfg, params = setup
    eng = Engine(cfg, params, serve_cfg=ServeConfig(
        max_seq=48, max_slots=3, paged=True, page_size=8, num_pages=48,
        prefill_chunk=8, prefix_cache=True))
    common = _prompts(cfg, [16], seed=3)[0]
    tails = _prompts(cfg, [5, 9, 7], seed=4)
    out, census = _run_censused(eng, [common + t for t in tails],
                                max_new_tokens=4)
    assert census.rounds > 0
    assert census.check(max_per_round=1) == []


def test_transfer_census_speculative(setup):
    """Speculative engine: one transfer per fused draft+verify round."""
    cfg, params = setup
    eng = Engine(cfg, params, policy=W4A16_T3,
                 serve_cfg=ServeConfig(max_seq=48, max_batch=2,
                                       spec_terms=2, spec_lookahead=2))
    out, census = _run_censused(eng, _prompts(cfg, [8, 8]),
                                max_new_tokens=4)
    assert census.rounds > 0
    assert census.check(max_per_round=1) == []


def test_transfer_census_and_retrace_qos_masked(setup):
    """Mixed-tier run: one transfer per scheduler round even with multiple
    masked dispatches per round, and the per-budget jit caches stay at ONE
    trace each (membership/temperature changes never retrace)."""
    cfg, params = setup
    eng = Engine(cfg, params, policy=W4A16_T3, serve_cfg=ServeConfig(
        max_seq=48, max_slots=4, tier_budgets=(("k2", 2), ("k1", 1))))
    out, census = _run_censused(
        eng, _prompts(cfg, [8, 8, 8, 8]), max_new_tokens=5,
        qualities=["full", "k2", "k1", "k2"])
    assert census.rounds > 0
    # one scheduler-round transfer; tier dispatches within a round are
    # marked as separate groups, each issuing at most the contracted one
    assert census.check(max_per_round=1) == []
    # retrace tripwire: one trace per distinct term budget, pinned
    table = {f"decode[k={k}]": fn
             for k, fn in eng._decode_by_budget.items()}
    assert check_no_retrace(table) == []
    for name, size in A.jit_cache_sizes(table).items():
        assert size in (0, 1), (name, size)


def test_engine_decode_caches_pinned_across_reconfig(setup):
    """Changing eos_id/temperature between runs must not retrace the fused
    step (they are dynamic operands of one cached trace)."""
    cfg, params = setup
    eng = Engine(cfg, params, serve_cfg=ServeConfig(max_seq=48, max_batch=2))
    for temp, eos in ((0.0, -1), (0.7, 5)):
        eng.sc = ServeConfig(max_seq=48, max_batch=2,
                             temperature=temp, eos_id=eos)
        for p in _prompts(cfg, [8, 8], seed=int(temp * 10)):
            eng.add_request(p)
        eng.run(max_new_tokens=3)
    assert A.jit_cache_sizes({"decode": eng._decode})["decode"] == 1
