"""Data pipeline: determinism, resume, host sharding, learnability."""
import numpy as np

from repro.configs.base import get_arch
from repro.train.data import SyntheticLM, make_batch, make_host_loader


def test_deterministic_by_step():
    src = SyntheticLM(vocab_size=256, seq_len=32)
    a = src.batch(step=5, batch_size=4)
    b = src.batch(step=5, batch_size=4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch(step=6, batch_size=4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_host_sharding_disjoint():
    src = SyntheticLM(vocab_size=256, seq_len=32)
    a = src.batch(step=0, batch_size=4, host_id=0)
    b = src.batch(step=0, batch_size=4, host_id=1)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_loader_resume_identical():
    cfg = get_arch("qwen2_1_5b", smoke=True)
    full = [next(make_host_loader(cfg, 16, 4, start_step=i)) for i in range(6)]
    resumed = make_host_loader(cfg, 16, 4, start_step=3)
    for i in range(3):
        np.testing.assert_array_equal(full[3 + i]["tokens"], next(resumed)["tokens"])


def test_markov_structure_learnable():
    """Bigram statistics are far from uniform — the stream is learnable."""
    src = SyntheticLM(vocab_size=256, seq_len=512)
    toks = src.batch(0, 8)["tokens"]
    v = 128  # active vocabulary
    counts = np.zeros((v, v))
    for row in toks:
        np.add.at(counts, (row[:-1], row[1:]), 1)
    rowmax = counts.max(axis=1)
    rowsum = np.maximum(counts.sum(axis=1), 1)
    assert (rowmax / rowsum)[rowsum > 10].mean() > 0.3  # peaked transitions


def test_arch_aware_batches():
    vlm = get_arch("llama_3_2_vision_90b", smoke=True)
    b = make_batch(vlm, 16, 2, 0)
    assert "image_emb" in b and b["image_emb"].shape == (2, 8, 32)
    audio = get_arch("hubert_xlarge", smoke=True)
    b = make_batch(audio, 16, 2, 0)
    assert "frames" in b and b["frames"].shape == (2, 16, 24)
    assert b["labels"].max() < audio.vocab_size
