"""Benchmark harness: one module per paper table/figure + kernels + roofline.
Prints ``name,us_per_call,derived`` CSV rows (stdout) and writes the kernel
perf trajectory to ``benchmarks/results/BENCH_kernels.json``.  Run:
    PYTHONPATH=src python -m benchmarks.run [--only tableN]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

MODULES = (
    "table1_accuracy", "table2_bitsweep", "table3_cost", "table4_nlp",
    "table5_ablation", "table6_llm", "fig4_convergence", "kernel_bench",
    "roofline", "perf_variants",
)

BENCH_JSON = os.path.join(os.path.dirname(__file__), "results", "BENCH_kernels.json")


def _write_kernel_json(path: str) -> None:
    from benchmarks import kernel_bench
    if not kernel_bench.RECORDS:
        return
    os.makedirs(os.path.dirname(path), exist_ok=True)
    payload = {
        "backend": "interpret-cpu",
        "note": "us_per_call times the interpret-mode harness (NOT TPU perf);"
                " dispatch counts and modeled bytes are backend-invariant",
        "records": kernel_bench.RECORDS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path} ({len(kernel_bench.RECORDS)} records)", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter on module name")
    ap.add_argument("--json-out", default=BENCH_JSON,
                    help="where to write BENCH_kernels.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    ran_kernels = False
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            mod.run()
            ran_kernels = ran_kernels or mod_name == "kernel_bench"
        except Exception as e:
            failed.append(mod_name)
            print(f"{mod_name},0,ERROR:{type(e).__name__}:{e}", file=sys.stderr)
            traceback.print_exc(file=sys.stderr)
    if ran_kernels:
        _write_kernel_json(args.json_out)
    if failed:
        raise SystemExit(f"benchmark modules failed: {failed}")


if __name__ == "__main__":
    main()
