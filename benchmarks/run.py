"""Benchmark harness: one module per paper table/figure + kernels + roofline.
Prints ``name,us_per_call,derived`` CSV rows (stdout).  Run:
    PYTHONPATH=src python -m benchmarks.run [--only tableN]
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = (
    "table1_accuracy", "table2_bitsweep", "table3_cost", "table4_nlp",
    "table5_ablation", "table6_llm", "fig4_convergence", "kernel_bench",
    "roofline", "perf_variants",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter on module name")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            mod.run()
        except Exception as e:
            failed.append(mod_name)
            print(f"{mod_name},0,ERROR:{type(e).__name__}:{e}", file=sys.stderr)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark modules failed: {failed}")


if __name__ == "__main__":
    main()
