"""Expert-parallel MoE serving vs the replicated baseline (DESIGN.md §15).

For each fake-device count (1/2/4) a subprocess (the main process must keep
1 device, per the dry-run isolation contract) quantizes the MoE smoke model
(``grok_1_314b``: top-2 routing + softcaps), serves the same Zipf
mixed-length continuous-batching workload (``serving_bench.make_workload``)
under ``placement="replicated"`` and ``placement="expert"`` (stacked
per-expert expansions sharded over the "expert" mesh axis, grouped series
GEMM, one int32 psum), asserts the generated token streams are IDENTICAL,
and reports decode throughput, per-device HBM residency and the
scheduler's expert-load imbalance telemetry (``last_run_stats["moe"]``).

Emits ``benchmarks/results/BENCH_moe.json``::

    {"workload": {...},
     "rows": [{"devices": n,
               "replicated": {"decode_tokens_per_sec": ..., "moe": {...}},
               "expert":     {..., "param_bytes_per_device": ...},
               "tokens_identical": true}, ...]}

Run:  PYTHONPATH=src python benchmarks/moe_serving_bench.py [--tiny]
(CPU wall-clock; fake devices share one CPU, so tok/s falls with device
count here — the backend-invariant columns are per-device HBM, the expert
imbalance telemetry, and token identity.  On real accelerators each expert
shard is a physical chip.)
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "results", "BENCH_moe.json")

ARCH = "grok_1_314b"


def _worker(args) -> None:
    """Run inside the fake-device subprocess: serve both placements."""
    import time

    import jax

    from repro.api import QuantRecipe, Runtime, quantize
    from repro.configs.base import get_arch
    from repro.core.policy import W4A4
    from repro.dist.expert_parallel import make_moe_mesh
    from repro.infer import kvcache
    from repro.infer.serve import ServeConfig
    from repro.models import model as M
    from benchmarks.serving_bench import make_workload

    n_dev = args.devices
    assert jax.device_count() >= n_dev, (jax.device_count(), n_dev)
    cfg = get_arch(ARCH, smoke=True)
    assert cfg.num_experts % n_dev == 0, (cfg.num_experts, n_dev)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    art = quantize(params, QuantRecipe(method="fpxint", policy=W4A4,
                                       arch=ARCH, smoke=True))
    reqs = make_workload(cfg, args.requests, args.max_new, seed=args.seed)
    sc = ServeConfig(max_seq=args.max_seq, max_batch=args.slots,
                     max_slots=args.slots)

    def serve(placement):
        mesh = make_moe_mesh(n_dev) if placement == "expert" else None
        rt = Runtime(art, backend="ref", cfg=cfg, mesh=mesh,
                     placement=placement)
        eng = rt.serve(sc)
        for toks, budget in reqs:
            eng.add_request(toks, max_new_tokens=budget)
        t0 = time.perf_counter()
        out = eng.run(max_new_tokens=args.max_new)
        wall = time.perf_counter() - t0
        st = dict(eng.last_run_stats)
        cache_b = kvcache.total_cache_bytes(cfg, st["n_slots"], args.max_seq)
        pbd = kvcache.param_bytes_per_device(eng.params)
        st.update(wall_seconds=wall,
                  param_bytes_per_device=pbd,
                  cache_bytes_per_device=cache_b,
                  hbm_per_device_bytes=pbd + cache_b)
        return out, st

    out_rep, st_rep = serve("replicated")
    out_ep, st_ep = serve("expert")
    row = {
        "devices": n_dev,
        "replicated": st_rep,
        "expert": st_ep,
        "tokens_identical": out_ep == out_rep,
        "hbm_per_device_saving": (1.0 - st_ep["hbm_per_device_bytes"]
                                  / st_rep["hbm_per_device_bytes"]),
    }
    assert row["tokens_identical"], \
        f"expert placement diverged from replicated on {n_dev} devices"
    assert st_ep["moe"] == st_rep["moe"], "telemetry must be placement-blind"
    assert st_ep["moe"]["drop_fraction"] == 0.0   # serving routing: dropless
    with open(args.worker_out, "w") as f:
        json.dump(row, f)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized run (fewer requests/tokens/device counts)")
    ap.add_argument("--devices", type=int, default=0,
                    help="(internal) worker mode: run on this many fake devices")
    ap.add_argument("--device-counts", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--worker-out", default=None)
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args(argv)
    if args.tiny:
        args.requests, args.max_new = 6, 4
        args.device_counts = [1, 2, 4]

    if args.devices:          # worker mode (inside the fake-device process)
        _worker(args)
        return None

    rows = []
    for n in args.device_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["REPRO_NO_PALLAS"] = "1"   # sharded placements serve the ref path
        env["PYTHONPATH"] = (REPO + os.pathsep + os.path.join(REPO, "src")
                             + os.pathsep + env.get("PYTHONPATH", ""))
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tf:
            worker_out = tf.name
        cmd = [sys.executable, os.path.abspath(__file__),
               "--devices", str(n), "--worker-out", worker_out,
               "--requests", str(args.requests), "--slots", str(args.slots),
               "--max-new", str(args.max_new), "--max-seq", str(args.max_seq),
               "--seed", str(args.seed)]
        proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                              timeout=1800)
        if proc.returncode != 0:
            raise RuntimeError(
                f"{n}-device worker failed:\n{proc.stdout}\n{proc.stderr[-3000:]}")
        with open(worker_out) as f:
            row = json.load(f)
        os.unlink(worker_out)
        rows.append(row)
        e, r = row["expert"], row["replicated"]
        moe = e["moe"]
        print(f"devices={n}: expert decode {e['decode_tokens_per_sec']:.1f} "
              f"tok/s (replicated {r['decode_tokens_per_sec']:.1f}), "
              f"per-device HBM {e['hbm_per_device_bytes']/1e6:.2f} MB vs "
              f"{r['hbm_per_device_bytes']/1e6:.2f} MB "
              f"({row['hbm_per_device_saving']*100:.0f}% saved), imbalance "
              f"{moe['imbalance']:.2f}, drops {moe['drop_fraction']:.2f}, "
              f"tokens identical: {row['tokens_identical']}")

    payload = {
        "arch": f"{ARCH} (smoke: 2L d64 E=4 top-2)",
        "backend": "cpu (fake devices share one CPU: wall-clock tok/s falls "
                   "with device count here; per-device HBM, the imbalance "
                   "telemetry and token identity are backend-invariant)",
        "workload": {
            "requests": args.requests,
            "length_distribution": "zipf(1.0) over [4..27] "
                                   "(serving_bench.make_workload)",
            "max_new_tokens": args.max_new,
            "slots": args.slots,
            "max_seq": args.max_seq,
            "policy": "w4a4 (per-expert quantizers, grouped series GEMM)",
            "routing": "token (dropless serving contract, DESIGN.md §15)",
        },
        "rows": rows,
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}", file=sys.stderr)
    return payload


if __name__ == "__main__":
    main()
