"""Shared benchmark substrate: trained proxy models, eval metrics, timing.

No ImageNet/SQuAD ships in the container, so each paper table is reproduced
as a *proxy*: a smoke-scale model of the right family trained to convergence
on the deterministic synthetic task (train/data.py), then PTQ'd with the
method under test.  The comparisons (ours vs 1-term RTN vs GPTQ-lite etc.)
therefore isolate exactly what the paper's tables isolate — the
representation — while being runnable on CPU in seconds.

Trained params are cached under /tmp so repeated benchmark runs are fast.
"""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.dist import checkpoint as CKPT
from repro.models import model as M
from repro.models.layers import FP, QuantContext
from repro.train.data import make_batch
from repro.train.train_step import TrainConfig, loss_fn, make_train_step

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "/tmp/repro_bench_models")


def trained_model(arch: str, steps: int = 80, seq: int = 64, batch: int = 8,
                  lr: float = 3e-3, seed: int = 0):
    """Train (or load cached) a smoke model of the given arch."""
    cfg = get_arch(arch, smoke=True)
    ckpt_dir = os.path.join(CACHE_DIR, f"{arch}_s{steps}_q{seq}_b{batch}_seed{seed}")
    template = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(seed), cfg, dtype=jnp.float32))
    if CKPT.latest_step(ckpt_dir) is not None:
        params, _ = CKPT.restore(ckpt_dir, template)
        return cfg, params
    params = M.init_params(jax.random.PRNGKey(seed), cfg, dtype=jnp.float32)
    opt, step = make_train_step(cfg, TrainConfig(lr=lr, remat=False))
    opt_state = opt.init(params)
    step = jax.jit(step)
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in make_batch(cfg, seq, batch, i, seed=seed).items()}
        params, opt_state, _ = step(params, opt_state, b)
    CKPT.save(ckpt_dir, steps, params)
    return cfg, params


def eval_metrics(cfg, params, qc: QuantContext = FP, *, n_batches: int = 4,
                 seq: int = 64, batch: int = 8, seed_base: int = 1000) -> Dict[str, float]:
    """Held-out loss + top-1 accuracy (the tables' accuracy proxy)."""
    losses, accs = [], []
    for i in range(n_batches):
        b = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, seq, batch, seed_base + i).items()}
        l, m = loss_fn(params, b, cfg, qc)
        losses.append(float(l))
        accs.append(float(m["accuracy"]))
    return {"loss": float(np.mean(losses)), "accuracy": float(np.mean(accs)),
            "ppl": float(np.exp(np.mean(losses)))}


def eval_artifact(cfg, artifact, *, backend: str = "ref", n_batches: int = 4,
                  seq: int = 64, batch: int = 8, seed_base: int = 1000) -> Dict[str, float]:
    """Held-out metrics through the unified API: every method's artifact is
    evaluated by the same Runtime.lm_loss code path (Tables 1-6 contract)."""
    from repro.api import Runtime

    rt = Runtime(artifact, backend=backend, cfg=cfg)
    losses, accs = [], []
    for i in range(n_batches):
        b = make_batch(cfg, seq, batch, seed_base + i)
        l, m = rt.lm_loss(b)
        losses.append(float(l))
        accs.append(float(m["accuracy"]))
    return {"loss": float(np.mean(losses)), "accuracy": float(np.mean(accs)),
            "ppl": float(np.exp(np.mean(losses)))}


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Wall-time a jax callable; returns microseconds per call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


class Row:
    """CSV accumulator: name,us_per_call,derived."""
    rows = []

    @classmethod
    def add(cls, name: str, us: float, derived):
        cls.rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}")

    @classmethod
    def flush(cls):
        out = list(cls.rows)
        cls.rows = []
        return out
