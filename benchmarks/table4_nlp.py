"""Table 4 proxy: encoder-family task (BERT stand-in = hubert-family smoke
encoder on frame classification) at W4A4 — ours vs 1-term RTN.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import Row, eval_metrics, trained_model
from repro.core.policy import W4A4
from repro.core.ptq import expand_params
from repro.models.layers import QuantContext


def run():
    cfg, params = trained_model("hubert_xlarge", steps=60)
    base = eval_metrics(cfg, params)
    Row.add("table4/encoder/full", 0.0, f"acc={base['accuracy']:.4f}")
    q = expand_params(params, W4A4)
    m = eval_metrics(cfg, q, QuantContext(policy=W4A4))
    Row.add("table4/encoder/ours_w4a4", 0.0, f"acc={m['accuracy']:.4f}")
    rtn = dataclasses.replace(W4A4, w_terms=1, a_terms=1, w_saturating=False)
    mr = eval_metrics(cfg, expand_params(params, rtn), QuantContext(policy=rtn))
    Row.add("table4/encoder/rtn_w4a4", 0.0, f"acc={mr['accuracy']:.4f}")


if __name__ == "__main__":
    run()
