"""Table 5: onlyA / onlyW ablation — expanding activations matters more.

onlyA: weights 1-term, activations multi-term;
onlyW: weights multi-term, activations 1-term;
ours:  both multi-term.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import Row, eval_metrics, trained_model
from repro.core.policy import W4A4
from repro.core.ptq import expand_params
from repro.models.layers import QuantContext


def run():
    for arch in ("qwen2_1_5b", "deepseek_7b"):
        cfg, params = trained_model(arch)
        variants = {
            "onlyA": dataclasses.replace(W4A4, w_terms=1, first_last_terms=1),
            "onlyW": dataclasses.replace(W4A4, a_terms=1),
            "ours": W4A4,
        }
        for name, pol in variants.items():
            q = expand_params(params, pol)
            m = eval_metrics(cfg, q, QuantContext(policy=pol))
            Row.add(f"table5/{arch}/{name}", 0.0, f"acc={m['accuracy']:.4f}")


if __name__ == "__main__":
    run()
