"""Table 6 proxy: W4A16 weight-only serving of the LM (the LLM/MMLU setting).

Methods: full / ours (2-term W4 series, FP activations) / normal (1-term RTN
W4 weight-only).  Derived: perplexity + accuracy on held-out stream.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import Row, eval_metrics, trained_model
from repro.core.policy import W4A16
from repro.core.ptq import expand_params
from repro.models.layers import QuantContext


def run():
    for arch in ("qwen2_1_5b", "recurrentgemma_9b"):
        cfg, params = trained_model(arch)
        base = eval_metrics(cfg, params)
        Row.add(f"table6/{arch}/full", 0.0,
                f"acc={base['accuracy']:.4f} ppl={base['ppl']:.3f}")
        q = expand_params(params, W4A16)
        m = eval_metrics(cfg, q, QuantContext(policy=W4A16))
        Row.add(f"table6/{arch}/ours_w4a16", 0.0,
                f"acc={m['accuracy']:.4f} ppl={m['ppl']:.3f}")
        rtn = dataclasses.replace(W4A16, w_terms=1, w_saturating=False,
                                  first_last_terms=1)
        mr = eval_metrics(cfg, expand_params(params, rtn), QuantContext(policy=rtn))
        Row.add(f"table6/{arch}/normal_w4a16", 0.0,
                f"acc={mr['accuracy']:.4f} ppl={mr['ppl']:.3f}")


if __name__ == "__main__":
    run()
