"""Table 6 proxy: W4A16 weight-only serving of the LM (the LLM/MMLU setting).

Methods: full / ours (2-term W4 series, FP activations) / normal (registry
``rtn``: 1-term min-max RTN weight-only) — all through the unified
Recipe -> Artifact -> Runtime path.  The ``ours`` row additionally
round-trips the INT4-packed artifact (planes stored 2/byte on disk, the
serving representation) to pin the packed format into the benchmark.
Derived: perplexity + accuracy on held-out stream.
"""
from __future__ import annotations

import os
import tempfile

from benchmarks.common import Row, eval_artifact, eval_metrics, trained_model
from repro.api import QuantArtifact, QuantRecipe, quantize
from repro.core.policy import W4A16


def run():
    for arch in ("qwen2_1_5b", "recurrentgemma_9b"):
        cfg, params = trained_model(arch)
        base = eval_metrics(cfg, params)
        Row.add(f"table6/{arch}/full", 0.0,
                f"acc={base['accuracy']:.4f} ppl={base['ppl']:.3f}")
        # ours: packed W4A16 artifact, saved + reloaded (the deploy product)
        art = quantize(params, QuantRecipe(method="fpxint", policy=W4A16,
                                           pack=True, arch=arch))
        path = os.path.join(tempfile.mkdtemp(), f"{arch}_w4a16")
        art.save(path)
        art = QuantArtifact.load(path)
        m = eval_artifact(cfg, art)
        Row.add(f"table6/{arch}/ours_w4a16", 0.0,
                f"acc={m['accuracy']:.4f} ppl={m['ppl']:.3f} packed={art.packed}")
        # normal: 1-term RTN weight-only (the paper's 'Normal' row)
        art = quantize(params, QuantRecipe(method="rtn", policy=W4A16,
                                           arch=arch))
        mr = eval_artifact(cfg, art)
        Row.add(f"table6/{arch}/normal_w4a16", 0.0,
                f"acc={mr['accuracy']:.4f} ppl={mr['ppl']:.3f}")


if __name__ == "__main__":
    run()
