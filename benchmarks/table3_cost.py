"""Table 3 proxy: quantization cost & model size — no calibration data, no
fine-tuning, seconds-scale quantization, size accounting incl. mixed
precision.  All rows come from the artifact's provenance metadata
(``quant_seconds``, ``expansion_stats``) — the unified API records the
paper's Quant-Time as a side effect of quantizing.
"""
from __future__ import annotations

from benchmarks.common import Row, eval_artifact, eval_metrics, trained_model
from repro.api import QuantRecipe, quantize
from repro.core.policy import ExpansionPolicy, W4A4

MIX = ExpansionPolicy(w_bits=2, a_bits=4, w_terms=2, a_terms=3,
                      mixed=(("attn", (2, 4)), ("mlp", (4, 4))),
                      first_last_bits=8)


def run():
    for arch in ("qwen2_1_5b", "mamba2_780m"):
        cfg, params = trained_model(arch)
        base = eval_metrics(cfg, params)
        Row.add(f"table3/{arch}/full", 0.0,
                f"acc={base['accuracy']:.4f} size=1.00x data=0 ft=none")
        for name, pol in (("w4a4", W4A4), ("w2mix", MIX)):
            art = quantize(params, QuantRecipe(method="fpxint", policy=pol,
                                               arch=arch))
            st = art.meta["expansion_stats"]
            m = eval_artifact(cfg, art)
            Row.add(f"table3/{arch}/{name}", art.quant_seconds * 1e6,
                    f"acc={m['accuracy']:.4f} size={1/st['compression']:.2f}x "
                    f"data=0 ft=none")


if __name__ == "__main__":
    run()
