"""QoS serving: per-tier lm-loss vs load, degradation behavior, deadlines.

Theorem 1 prices a quality ladder for free: the first ``k`` terms of every
FP=xINT expansion are a coherent lower-bit model sharing weights/scales/KV
layout with the full series, so one resident artifact serves ``full``/
``k2``/``k1`` tiers per request (DESIGN.md §11).  This bench measures what
that ladder costs and buys:

* **quality axis** — lm-loss of each tier's statically-truncated context
  (``Runtime.lm_loss(batch, term_budget=k)``): the model quality a request
  of that tier receives when NOT degraded;
* **load sweep** — the same mixed-tier workload at increasing request loads
  on a fixed slot pool, load-adaptive degradation ON: per-tier served
  tokens, mean effective terms, degraded-step fraction, deadline hit rate,
  and an *effective* lm-loss (nominal/floor losses mixed by the measured
  degraded-step fraction);
* **chaos probe** — a seeded HBM-squeeze run asserting the robustness
  contract: the scheduler degrades instead of rejecting, recovers when the
  window passes, and leaks no slot.  The CI ``chaos-smoke`` job re-asserts
  these invariants from the emitted JSON.

Emits ``benchmarks/results/BENCH_qos.json``.

Run:  PYTHONPATH=src python benchmarks/qos_bench.py [--tiny]
(CPU wall-clock; losses, term counts and hit rates are backend-invariant.)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

from repro.api import QuantRecipe, Runtime, quantize
from repro.configs.base import get_arch
from repro.core.policy import ExpansionPolicy
from repro.infer import qos as Q
from repro.infer.serve import ServeConfig

OUT_JSON = os.path.join(os.path.dirname(__file__), "results",
                        "BENCH_qos.json")

# weight-only with THREE weight terms (the deployment-typical W4A16 shape):
# the k2/k1 tiers are genuine truncations, not the full series
POLICY = ExpansionPolicy(w_bits=4, a_bits=16, w_terms=3, a_terms=0)
TIERS = (("k2", 2), ("k1", 1))
TIER_BUDGETS = {"full": 3, "k2": 2, "k1": 1}
FLOOR = min(b for _, b in TIERS)


def make_eval_batch(cfg, batch: int, seq: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (batch, seq))
    return {"tokens": toks, "labels": toks}


def tier_losses(rt, batch) -> dict:
    """lm-loss of each tier's truncated context (and the degradation
    floor) — the quality axis of the loss-vs-load table."""
    losses = {}
    for name, k in TIER_BUDGETS.items():
        loss, _ = rt.lm_loss(batch, term_budget=None if name == "full" else k)
        losses[name] = float(loss)
    floor_loss, _ = rt.lm_loss(batch, term_budget=FLOOR)
    losses["_floor"] = float(floor_loss)
    return losses


def make_workload(cfg, n_requests: int, max_new: int, seed: int = 0):
    """Mixed-tier, mixed-length workload: tiers round-robin full/k2/k1."""
    rng = np.random.default_rng(seed)
    names = list(TIER_BUDGETS)
    return [(rng.integers(0, cfg.vocab_size,
                          int(rng.integers(4, 20))).tolist(),
             names[i % len(names)])
            for i in range(n_requests)]


def run_load(rt, workload, *, slots: int, max_new: int, deadline_s: float,
             chaos=None) -> dict:
    eng = rt.serve(ServeConfig(
        max_seq=64, max_batch=slots, max_slots=slots, tier_budgets=TIERS,
        chaos=chaos))
    ids = []
    rejected = 0
    for toks, quality in workload:
        res = eng.add_request(toks, quality=quality, deadline_s=deadline_s)
        if isinstance(res, Q.Rejection):
            rejected += 1
        else:
            ids.append(res)
    t0 = time.perf_counter()
    out = eng.run(max_new_tokens=max_new)
    st = dict(eng.last_run_stats)
    st["wall_seconds"] = time.perf_counter() - t0
    st["rejected_at_admission"] = rejected
    st["served_requests"] = len(ids)
    return st


def per_tier_table(st, losses) -> dict:
    """The loss-vs-load rows: measured QoS counters + the effective
    lm-loss each tier received (nominal/floor losses mixed by the measured
    degraded-step fraction — exact when only two budgets are served)."""
    table = {}
    for name, ts in st.get("tiers", {}).items():
        frac = ts["degraded_step_fraction"]
        table[name] = {
            "requests": ts["requests"],
            "served_tokens": ts["served_tokens"],
            "nominal_terms": ts["nominal_terms"],
            "mean_effective_terms": round(ts["mean_effective_terms"], 4),
            "degraded_step_fraction": round(frac, 4),
            "deadline_hit_rate": ts["deadline_hit_rate"],
            "cancelled": ts["cancelled"],
            "lm_loss_nominal": losses[name],
            "lm_loss_effective": round(
                (1.0 - frac) * losses[name] + frac * losses["_floor"], 6),
        }
    return table


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized run (fewer requests/tokens)")
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--deadline-s", type=float, default=120.0,
                    help="per-request wall deadline (generous: hit rates "
                         "measure scheduling, not container speed)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args(argv)
    if args.tiny:
        args.max_new = 6

    cfg = get_arch("qwen2_1_5b", smoke=True)
    from repro.models import model as M
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    art = quantize(params, QuantRecipe(
        method="fpxint", policy=POLICY, arch="qwen2_1_5b", smoke=True,
        qos_tiers=TIERS))
    rt = Runtime(art, backend="ref", cfg=cfg)

    batch = make_eval_batch(cfg, batch=2 if args.tiny else 4,
                            seq=32 if args.tiny else 64, seed=args.seed)
    losses = tier_losses(rt, batch)
    print("tier lm-loss:", {k: round(v, 4) for k, v in losses.items()
                            if not k.startswith("_")})

    # load sweep: light (fits the pool) -> heavy (deep queue => the
    # controller degrades degradable tiers to keep deadlines)
    mult = (1, 3) if args.tiny else (1, 3, 6)
    sweep = []
    for m in mult:
        n_req = args.slots * m
        workload = make_workload(cfg, n_req, args.max_new, seed=args.seed)
        st = run_load(rt, workload, slots=args.slots, max_new=args.max_new,
                      deadline_s=args.deadline_s)
        assert st["slots_leaked"] == 0, "slot leak under load"
        assert st["queue_leftover"] == 0, "queue leftover under load"
        row = {
            "load": f"{m}x_slots",
            "requests": n_req,
            "slots": args.slots,
            "decode_tokens_per_sec": round(st["decode_tokens_per_sec"], 2),
            "degraded_rounds": st["qos"]["degraded_rounds"],
            "per_tier": per_tier_table(st, losses),
        }
        sweep.append(row)
        hits = {k: v["deadline_hit_rate"] for k, v in row["per_tier"].items()}
        print(f"load {row['load']}: {n_req} reqs, "
              f"degraded_rounds={row['degraded_rounds']}, "
              f"deadline_hit={hits}")

    # chaos probe: a seeded HBM squeeze mid-run must degrade (not reject),
    # recover, and leak nothing — the CI chaos-smoke assertions' source
    chaos = Q.ChaosConfig(seed=args.seed, latency_p=0.2, latency_s=0.002,
                          fail_p=0.1, hbm_squeeze_start=2,
                          hbm_squeeze_steps=4, hbm_squeeze_frac=0.4)
    workload = make_workload(cfg, args.slots * 3, args.max_new,
                             seed=args.seed)
    st = run_load(rt, workload, slots=args.slots, max_new=args.max_new,
                  deadline_s=args.deadline_s, chaos=chaos)
    chaos_row = {
        "config": dataclassdict(chaos),
        "served_requests": st["served_requests"],
        "rejected_at_admission": st["rejected_at_admission"],
        "degraded_rounds": st["qos"]["degraded_rounds"],
        "degrade_transitions": st["qos"]["degrade_transitions"],
        "degraded_at_end": st["qos"]["degraded_now"],
        "usable_slots_min": st["usable_slots_min"],
        "dispatch_retries": st["dispatch_retries"],
        "injected": st["chaos"],
        "watchdog": st["watchdog"],
        "slots_leaked": st["slots_leaked"],
        "queue_leftover": st["queue_leftover"],
        "cancelled": st["cancelled"],
        "per_tier": per_tier_table(st, losses),
    }
    assert chaos_row["slots_leaked"] == 0, "slot leak under chaos"
    assert not chaos_row["degraded_at_end"], "no recovery after squeeze"
    assert chaos_row["degraded_rounds"] > 0, "squeeze never degraded"
    print(f"chaos: degraded_rounds={chaos_row['degraded_rounds']}, "
          f"retries={chaos_row['dispatch_retries']}, "
          f"recovered={not chaos_row['degraded_at_end']}, leaks=0")

    payload = {
        "arch": "qwen2_1_5b (smoke)",
        "backend": "cpu",
        "policy": "w4a16 weight-only, w_terms=3",
        "tiers": {name: {"term_budget": k, "lm_loss": losses[name]}
                  for name, k in TIER_BUDGETS.items()},
        "degradation_floor_terms": FLOOR,
        "note": "lm_loss_effective mixes nominal/floor losses by the "
                "measured degraded-step fraction; wall-clock numbers are "
                "container-CPU, everything else is backend-invariant",
        "workload": {
            "tier_mix": "round-robin full/k2/k1",
            "prompt_lengths": "uniform [4, 20)",
            "max_new_tokens": args.max_new,
            "deadline_s": args.deadline_s,
        },
        "load_sweep": sweep,
        "chaos": chaos_row,
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}", file=sys.stderr)
    return payload


def dataclassdict(dc) -> dict:
    import dataclasses
    return dataclasses.asdict(dc)


if __name__ == "__main__":
    main()
