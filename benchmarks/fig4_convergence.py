"""Fig. 4 reproduction:
(a) saturation ablation — Laplace clip on/off for weights & activations;
(b) expansion-count sweep — maxdiff + accuracy vs number of terms
    (the 'expand until maxdiff < 1e-4' stopping rule).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import Row, eval_metrics, trained_model
from repro.core.policy import W4A4
from repro.core.ptq import expand_params, max_weight_residual
from repro.models.layers import QuantContext


def run():
    cfg, params = trained_model("qwen2_1_5b")
    # (a) saturation ablation
    for wsat in (True, False):
        for asat in (True, False):
            pol = dataclasses.replace(W4A4, w_saturating=wsat, a_saturating=asat)
            q = expand_params(params, pol)
            m = eval_metrics(cfg, q, QuantContext(policy=pol))
            Row.add(f"fig4a/wsat={int(wsat)}_asat={int(asat)}", 0.0,
                    f"acc={m['accuracy']:.4f}")
    # (b) expansion count sweep
    for t in (1, 2, 3, 4, 5):
        pol = dataclasses.replace(W4A4, w_terms=min(t, 3), a_terms=t,
                                  first_last_terms=min(t, 2))
        q = expand_params(params, pol)
        m = eval_metrics(cfg, q, QuantContext(policy=pol))
        maxdiff = float(max_weight_residual(params, q))
        Row.add(f"fig4b/terms={t}", 0.0,
                f"acc={m['accuracy']:.4f} maxdiff={maxdiff:.2e}")


if __name__ == "__main__":
    run()
