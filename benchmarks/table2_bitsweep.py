"""Table 2 proxy: accuracy + quantization time across bit settings.

Paper: W3A3 / W2A4 / W4A2 / W8A8 on ResNet-18; here on the trained proxy LM.
us_per_call = quantization wall time (the paper's 'Quant-Time' row).
"""
from __future__ import annotations

from benchmarks.common import Row, eval_metrics, trained_model
from repro.core.policy import NAMED_POLICIES
from repro.core.ptq import expand_params_timed
from repro.models.layers import QuantContext

SETTINGS = ("w3a3", "w2a4", "w4a2", "w8a8", "w4a4")


def run():
    cfg, params = trained_model("qwen2_1_5b")
    base = eval_metrics(cfg, params)
    Row.add("table2/full_prec", 0.0, f"acc={base['accuracy']:.4f}")
    for setting in SETTINGS:
        pol = NAMED_POLICIES[setting]
        q, seconds = expand_params_timed(params, pol)
        m = eval_metrics(cfg, q, QuantContext(policy=pol))
        Row.add(f"table2/{setting}", seconds * 1e6, f"acc={m['accuracy']:.4f}")


if __name__ == "__main__":
    run()
