"""Self-speculative decoding: truncated-series drafts vs plain slot serving.

Theorem 1 makes the first ``k`` terms of every FP=xINT expansion a coherent
low-bit model that shares weights, scales, and KV layout with the full
series — a *free* draft model.  This bench serves the same mixed-length
workload three ways on the slot scheduler: non-speculative baseline, then
speculative at two term budgets ``k``, and

* ASSERTS greedy token identity (the spec engine must emit exactly the
  baseline stream — the speedup is pure acceptance-rate arithmetic);
* reports per-budget acceptance rate, tokens/round, and decode tok/s.

Emits ``benchmarks/results/BENCH_spec_serving.json``::

    {"workload": {...},
     "baseline": {"decode_tokens_per_sec": ...},
     "spec": {"k=1": {"acceptance_rate": ..., ...},
              "k=2": {...}},
     "tokens_identical": true}

Run:  PYTHONPATH=src python benchmarks/spec_serving_bench.py [--tiny]
(CPU wall-clock; acceptance rate and tokens/round are backend-invariant.)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.core.policy import ExpansionPolicy
from repro.api import QuantRecipe, Runtime, quantize
from repro.infer.serve import ServeConfig

OUT_JSON = os.path.join(os.path.dirname(__file__), "results",
                        "BENCH_spec_serving.json")

# weight-only so serving reads FP activations (the deployment-typical W4A16
# shape, Table 6) with THREE weight terms — budgets k=1 and k=2 are real
# truncations, not the full series
POLICY = ExpansionPolicy(w_bits=4, a_bits=16, w_terms=3, a_terms=0)


def draft_weight_ratio(params, k: int) -> float:
    """Bytes a k-term draft step reads / bytes a full-series step reads.

    Memory-bound decode is dominated by weight reads: truncation drops the
    trailing planes+scales of every ExpandedTensor; everything else
    (embeddings, norms, 1-term first/last layers) is read in full either
    way."""
    import jax as _jax
    from repro.core.expansion import ExpandedTensor
    from repro.infer.kvcache import param_bytes

    is_et = lambda l: isinstance(l, ExpandedTensor)
    truncated = _jax.tree_util.tree_map(
        lambda l: l.truncate(k) if is_et(l) else l, params, is_leaf=is_et)
    return param_bytes(truncated) / param_bytes(params)


def make_workload(cfg, n_requests: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    lengths = np.arange(4, 28)
    ranks = np.arange(1, len(lengths) + 1, dtype=np.float64)
    pz = ranks ** -1.0
    pz /= pz.sum()
    return [(rng.integers(0, cfg.vocab_size,
                          int(rng.choice(lengths, p=pz))).tolist(),
             int(rng.integers(max(2, max_new // 2), max_new + 1)))
            for _ in range(n_requests)]


def run_once(rt, reqs, *, slots: int, max_seq: int, max_new: int,
             spec_terms: int, lookahead: int) -> dict:
    eng = rt.serve(ServeConfig(
        max_seq=max_seq, max_batch=slots, max_slots=slots,
        spec_terms=spec_terms, spec_lookahead=lookahead))
    ids = [eng.add_request(t, max_new_tokens=m) for t, m in reqs]
    t0 = time.perf_counter()
    out = eng.run(max_new_tokens=max_new)
    wall = time.perf_counter() - t0
    st = dict(eng.last_run_stats)
    st["wall_seconds"] = wall
    st["outputs"] = [out[i] for i in ids]
    return st


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized run (fewer requests/tokens)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--lookahead", type=int, default=4)
    ap.add_argument("--term-budgets", type=int, nargs="+", default=[1, 2])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args(argv)
    if args.tiny:
        args.requests, args.max_new = 8, 8

    cfg = get_arch("qwen2_1_5b", smoke=True)
    from repro.models import model as M
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    art = quantize(params, QuantRecipe(
        method="fpxint", policy=POLICY, arch="qwen2_1_5b", smoke=True))
    rt = Runtime(art, backend="ref", cfg=cfg)
    reqs = make_workload(cfg, args.requests, args.max_new, seed=args.seed)
    kw = dict(slots=args.slots, max_seq=args.max_seq, max_new=args.max_new,
              lookahead=args.lookahead)

    # warmup compiles; the timed passes measure steady-state serving
    run_once(rt, reqs, spec_terms=0, **kw)
    base = run_once(rt, reqs, spec_terms=0, **kw)
    print(f"baseline : decode {base['decode_tokens_per_sec']:.1f} tok/s, "
          f"{base['decode_steps']} steps")

    spec_results = {}
    identical = True
    gamma = args.lookahead
    for k in args.term_budgets:
        run_once(rt, reqs, spec_terms=k, **kw)
        st = run_once(rt, reqs, spec_terms=k, **kw)
        same = st.pop("outputs") == base["outputs"]
        identical &= same
        st["tokens_identical_to_baseline"] = same
        st["decode_speedup_vs_baseline"] = (
            st["decode_tokens_per_sec"]
            / max(base["decode_tokens_per_sec"], 1e-9))
        # backend-invariant wins: dispatch reduction (each spec round is ONE
        # fused dispatch, vs one per token), and the memory-bound model — on
        # weight-bandwidth-bound hardware a round reads gamma draft-weight
        # passes + one full pass (the verify chunk reads weights ONCE for
        # all gamma+1 positions) and yields 1 + acceptance*gamma tokens
        r_draft = draft_weight_ratio(rt.params, k)
        st["dispatch_reduction_vs_baseline"] = (
            base["decode_steps"] / max(st["decode_steps"], 1))
        st["draft_weight_byte_ratio"] = r_draft
        st["modeled_membound_speedup"] = (
            (1.0 + st["acceptance_rate"] * gamma)
            / (gamma * r_draft + 1.0))
        spec_results[f"k={k}"] = st
        print(f"spec k={k} : decode {st['decode_tokens_per_sec']:.1f} tok/s "
              f"({st['decode_speedup_vs_baseline']:.2f}x wall on CPU), "
              f"acceptance {st['acceptance_rate']:.2f}, "
              f"{st['tokens_per_round']:.2f} tok/round, "
              f"{st['dispatch_reduction_vs_baseline']:.2f}x fewer dispatches, "
              f"modeled mem-bound {st['modeled_membound_speedup']:.2f}x, "
              f"identical={same}")
        assert same, f"speculative k={k} diverged from the baseline stream"
    base.pop("outputs")

    payload = {
        "arch": "qwen2_1_5b (smoke)",
        "backend": "cpu",
        "policy": "w4a16 weight-only, w_terms=3",
        "note": "wall-clock on the CI/container CPU backend; acceptance "
                "rate, tokens/round and decode_steps are backend-invariant",
        "workload": {
            "requests": args.requests,
            "length_distribution": "zipf(1.0) over [4..27]",
            "max_new_tokens": args.max_new,
            "slots": args.slots,
            "max_seq": args.max_seq,
            "spec_lookahead": args.lookahead,
        },
        "baseline": base,
        "spec": spec_results,
        "tokens_identical": identical,
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}", file=sys.stderr)
    return payload


if __name__ == "__main__":
    main()
