"""Kernel microbenchmarks (functional CPU timings — interpret mode executes
the kernel body in Python, so us_per_call documents the harness, NOT TPU
perf; the TPU-side analysis lives in roofline.py).  Cross-checks: fused
kernel == ref == fp32 within tolerance at benchmark sizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_fn
from repro.core import expansion as E
from repro.kernels import ops
from repro.kernels.pack import pack_int4


def run():
    rng = np.random.default_rng(0)
    for m, k, n in ((128, 512, 256), (256, 1024, 512)):
        x = jnp.array(rng.normal(size=(m, k)).astype(np.float32))
        w = jnp.array(rng.normal(size=(k, n)).astype(np.float32))
        w_et = E.expand(w, 4, 2, per_channel=True)
        s1 = E.first_scale(jnp.max(jnp.abs(x)), 4)

        fp = jax.jit(lambda a, b: a @ b)
        us_fp = time_fn(fp, x, w)
        Row.add(f"kernel/fp_matmul/{m}x{k}x{n}", us_fp, "ref")

        f_kernel = lambda: ops.series_matmul(x, s1, w_et.planes, w_et.scales,
                                             a_bits=4, a_terms=3, use_kernel=True)
        f_ref = lambda: ops.series_matmul(x, s1, w_et.planes, w_et.scales,
                                          a_bits=4, a_terms=3, use_kernel=False)
        us_k = time_fn(f_kernel)
        us_r = time_fn(f_ref)
        err = float(jnp.max(jnp.abs(f_kernel() - f_ref())))
        Row.add(f"kernel/series_matmul_pallas/{m}x{k}x{n}", us_k, f"maxerr_vs_ref={err:.1e}")
        Row.add(f"kernel/series_matmul_jnp/{m}x{k}x{n}", us_r, "oracle")

        fq = lambda: ops.residual_quantize(x, s1, bits=4, terms=3, use_kernel=True)
        Row.add(f"kernel/residual_quantize/{m}x{k}", time_fn(fq), "3 planes")

        # packed INT4 weight-only GEMM (W4A16 serving kernel)
        et4 = E.expand(w, 4, 2, per_channel=True, pack_safe=True)
        packed = pack_int4(et4.planes)
        fp4 = lambda: ops.packed_dequant_matmul(x, packed, et4.scales, use_kernel=True)
        err4 = float(jnp.max(jnp.abs(fp4() - ops.packed_dequant_matmul(
            x, packed, et4.scales, use_kernel=False))))
        Row.add(f"kernel/packed_dequant_matmul/{m}x{k}x{n}", time_fn(fp4),
                f"maxerr_vs_ref={err4:.1e} bytes=0.5/val/term")


if __name__ == "__main__":
    run()
