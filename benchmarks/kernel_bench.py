"""Kernel microbenchmarks (functional CPU timings — interpret mode executes
the kernel body in Python, so us_per_call documents the harness, NOT TPU
perf; the TPU-side analysis lives in roofline.py).  Cross-checks: fused
kernel == ref == fp32 within tolerance at benchmark sizes.

Besides the CSV rows, every case appends a structured record to ``RECORDS``
(us/call, maxerr vs ref, MXU dot dispatches per block from jaxpr
inspection, the autotuned block config, and the modeled HBM traffic of the
single-pass pipeline vs the seed's) — benchmarks/run.py dumps these to
``BENCH_kernels.json`` so the perf trajectory is tracked per PR.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_fn
from benchmarks.roofline import series_gemm_traffic
from repro.core import expansion as E
from repro.kernels import ops
from repro.kernels.pack import pack_int4

RECORDS: List[Dict[str, Any]] = []


def _record(name: str, us: float, maxerr: float, dispatches: int,
            cfg: ops.BlockConfig, extra: Dict[str, Any]) -> None:
    RECORDS.append({
        "name": name, "us_per_call": round(us, 2),
        "maxerr_vs_ref": maxerr, "gemm_dispatches_per_block": dispatches,
        "block_m": cfg.block_m, "block_n": cfg.block_n, "block_k": cfg.block_k,
        **extra,
    })


def run():
    RECORDS.clear()
    rng = np.random.default_rng(0)
    ta, tw = 3, 2
    for m, k, n in ((128, 512, 256), (256, 1024, 512)):
        x = jnp.array(rng.normal(size=(m, k)).astype(np.float32))
        w = jnp.array(rng.normal(size=(k, n)).astype(np.float32))
        w_et = E.expand(w, 4, tw, per_channel=True)
        s1 = E.first_scale(jnp.max(jnp.abs(x)), 4)

        fp = jax.jit(lambda a, b: a @ b)
        us_fp = time_fn(fp, x, w)
        Row.add(f"kernel/fp_matmul/{m}x{k}x{n}", us_fp, "ref")

        f_kernel = lambda: ops.series_matmul(x, s1, w_et.planes, w_et.scales,
                                             a_bits=4, a_terms=ta, use_kernel=True)
        f_ref = lambda: ops.series_matmul(x, s1, w_et.planes, w_et.scales,
                                          a_bits=4, a_terms=ta, use_kernel=False)
        us_k = time_fn(f_kernel)
        us_r = time_fn(f_ref)
        err = float(jnp.max(jnp.abs(f_kernel() - f_ref())))
        dispatches = ops.gemm_dispatch_count(
            ops.series_matmul, x, s1, w_et.planes, w_et.scales,
            a_bits=4, a_terms=ta, use_kernel=True)
        cfg = ops.select_block_config("series", m, k, n, ta, tw)
        traffic = series_gemm_traffic(m, k, n, ta, tw, block_m=cfg.block_m,
                                      block_n=cfg.block_n, block_k=cfg.block_k)
        Row.add(f"kernel/series_matmul_pallas/{m}x{k}x{n}", us_k,
                f"maxerr_vs_ref={err:.1e} dispatches={dispatches}")
        Row.add(f"kernel/series_matmul_jnp/{m}x{k}x{n}", us_r, "oracle")
        _record(f"series_matmul/{m}x{k}x{n}", us_k, err, dispatches, cfg, {
            "ta": ta, "tw": tw, "us_ref": round(us_r, 2), "us_fp": round(us_fp, 2),
            "model_bytes_single_pass": traffic["single_pass"]["bytes"],
            "model_bytes_seed": traffic["seed_fused"]["bytes"],
            "model_quant_elems": traffic["single_pass"]["quant_elems"],
        })

        fq = lambda: ops.residual_quantize(x, s1, bits=4, terms=ta, use_kernel=True)
        us_q = time_fn(fq)
        Row.add(f"kernel/residual_quantize/{m}x{k}", us_q, f"{ta} planes")
        _record(f"residual_quantize/{m}x{k}", us_q, 0.0, 0,
                ops.select_block_config("quant", m, 0, k, ta, 0), {"terms": ta})

        # packed INT4 weight-only GEMM (W4A16 serving kernel)
        et4 = E.expand(w, 4, tw, per_channel=True, pack_safe=True)
        packed = pack_int4(et4.planes)
        fp4 = lambda: ops.packed_dequant_matmul(x, packed, et4.scales, use_kernel=True)
        err4 = float(jnp.max(jnp.abs(fp4() - ops.packed_dequant_matmul(
            x, packed, et4.scales, use_kernel=False))))
        us_p = time_fn(fp4)
        disp4 = ops.gemm_dispatch_count(
            ops.packed_dequant_matmul, x, packed, et4.scales, use_kernel=True)
        Row.add(f"kernel/packed_dequant_matmul/{m}x{k}x{n}", us_p,
                f"maxerr_vs_ref={err4:.1e} dispatches={disp4} bytes=0.5/val/term")
        _record(f"packed_dequant_matmul/{m}x{k}x{n}", us_p, err4, disp4,
                ops.select_block_config("dequant", m, k, n, 0, tw), {"tw": tw})


if __name__ == "__main__":
    run()
