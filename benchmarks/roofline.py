"""Roofline analysis from the compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod 16x16 mesh (256 chips of TPU v5e):

  compute    = HLO_FLOPs_per_device / peak            (197 TFLOP/s bf16)
  compute*   = dtype-aware: int8 dot FLOPs credited at 394 TOPS (MXU int8)
  memory     = HLO_bytes_per_device / HBM bw          (819 GB/s)
  collective = wire_bytes_per_device / link bw        (50 GB/s/link, 1 link —
               conservative: multi-link torus routing would divide this)

HLO_FLOPs/bytes are the *loop-aware* totals (launch/hlo_cost.py): XLA's own
cost_analysis counts scan bodies once, so every number here is re-derived by
walking the call graph with known trip counts.  Wire bytes per collective:
  all-reduce      2(n-1)/n * payload      all-gather     (n-1)/n * output
  reduce-scatter  (n-1)   * output        all-to-all     (n-1)/n * payload
  collective-permute  1 * payload
MODEL_FLOPS = 6*N(_active)*tokens (train) / 2*N*tokens (inference) — the
"useful compute" yardstick; MODEL/HLO exposes remat + masking waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional

from benchmarks.common import Row
from repro.configs.base import SHAPES, get_arch

PEAK_BF16 = 197e12
PEAK_INT8 = 394e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def series_gemm_traffic(m: int, k: int, n: int, ta: int, tw: int, *,
                        block_m: int = 256, block_n: int = 256,
                        block_k: int = 512) -> Dict[str, float]:
    """Analytic HBM traffic + quantize work for the series GEMM, per pipeline.

    Three pipelines (kernels/series_matmul.py, DESIGN.md §3):

      naive        — residual planes materialized to HBM, ta*tw separate
                     plane GEMMs, f32 output read-modify-written per K step;
      seed_fused   — the seed kernel: planes quantized in VMEM (never hit
                     HBM) but re-quantized per N step, and the output block
                     read-modify-written once per K step;
      single_pass  — this PR: VMEM scratch accumulation (output written
                     once), quantize-once plane reuse across N blocks.

    ``quant_elems`` counts round/clip residual-chain element-passes (VPU
    work, not HBM bytes) — the quantize-once win shows up there.
    Returns bytes (f32 activations/outputs, int8 planes).
    """
    nbm, nbn, nbk = _cdiv(m, block_m), _cdiv(n, block_n), _cdiv(k, block_k)
    x_stream = 4.0 * m * k * nbn          # activation block per (j, kk) step
    w_stream = 1.0 * tw * k * n * nbm     # int8 weight planes per M strip
    scales = 4.0 * tw * n * nbm * nbk
    out_once = 4.0 * m * n
    out_rmw = 2.0 * 4.0 * m * n * nbk     # read+write per K step

    naive = {
        "bytes": (4.0 * m * k + ta * m * k)            # quantize pass
        + ta * tw * (1.0 * m * k * nbn + 1.0 * k * n * nbm) + out_rmw,
        "quant_elems": float(ta * m * k),
        "mxu_dispatches_per_block": float(ta * tw),
    }
    seed_fused = {
        "bytes": x_stream + w_stream + scales + out_rmw,
        "quant_elems": float(ta * m * k) * nbn,        # re-quantized per N step
        "mxu_dispatches_per_block": float(ta * tw),
    }
    single_pass = {
        "bytes": x_stream + w_stream + scales + out_once,
        "quant_elems": float(ta * m * k),              # quantize-once reuse
        "mxu_dispatches_per_block": float(ta),         # stacked-plane GEMM
    }
    return {
        "naive": naive, "seed_fused": seed_fused, "single_pass": single_pass,
        "bytes_saved_vs_seed": seed_fused["bytes"] - single_pass["bytes"],
        "t_memory_single_pass": single_pass["bytes"] / HBM_BW,
        "t_memory_seed": seed_fused["bytes"] / HBM_BW,
    }


def wire_bytes(collectives: Dict[str, Any]) -> float:
    total = 0.0
    for kind, v in collectives.items():
        b, n = v["bytes"], max(v.get("group", 0), 2)
        if kind == "all-reduce":
            total += 2 * (n - 1) / n * b
        elif kind == "all-gather":
            total += (n - 1) / n * b
        elif kind == "reduce-scatter":
            total += (n - 1) * b
        elif kind == "all-to-all":
            total += (n - 1) / n * b
        else:  # collective-permute
            total += b
    return total


def model_flops_per_device(arch: str, shape: str, chips: int) -> float:
    cfg = get_arch(arch)
    sh = SHAPES[shape]
    n = cfg.active_param_count()
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n * tokens / chips
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n * tokens / chips
    # decode: one token per sequence
    return 2.0 * n * sh.global_batch / chips


def load_cell(arch: str, shape: str, mesh: str = "single", tag: str = "") -> Optional[Dict]:
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh}{suffix}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def analyze_cell(rec: Dict[str, Any]) -> Dict[str, Any]:
    chips = 512 if rec["mesh"] == "multi" else 256
    la = rec["loop_aware"]
    flops, int_flops = la["flops"], la["int_dot_flops"]
    t_compute = flops / PEAK_BF16
    t_compute_dtype = (flops - int_flops) / PEAK_BF16 + int_flops / PEAK_INT8
    t_memory = la["bytes"] / HBM_BW
    wb = wire_bytes(la["collectives"])
    t_coll = wb / LINK_BW
    terms = {"compute": t_compute_dtype, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], chips)
    bound = max(terms.values())
    useful_t = (mf / PEAK_BF16)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        "t_compute_naive": t_compute, "t_compute": t_compute_dtype,
        "t_memory": t_memory, "t_collective": t_coll,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops": flops, "int_dot_flops": int_flops,
        "useful_ratio": mf / max(flops, 1.0),
        "wire_bytes": wb,
        "roofline_fraction": useful_t / max(bound, 1e-30),
        "hbm_bytes": la["bytes"],
        "temp_bytes": rec["memory"]["temp_bytes"],
        "arg_bytes": rec["memory"]["argument_bytes"],
    }


SUGGESTIONS = {
    "compute": "cut recompute (remat policy) and causal-mask waste (triangular-skip flash kernel); shift more GEMMs to the int8 MXU path",
    "memory": "pack INT planes (2xINT4/byte), fuse dequant into the GEMM (Pallas kernel does this on TPU), shrink microbatch working set",
    "collective": "reduce-scatter instead of all-reduce, shard to cut FSDP gather volume, overlap collectives with compute (latency-hiding scheduler), int8-compress payloads via the series codec",
}


def all_cells(mesh: str = "single", tag: str = "") -> List[Dict[str, Any]]:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh}{('_' + tag) if tag else ''}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if not rec.get("ok"):
            continue
        if tag == "" and rec.get("tag"):
            continue
        out.append(analyze_cell(rec))
    return out


def run():
    cells = all_cells("single")
    for c in cells:
        name = f"roofline/{c['arch']}/{c['shape']}"
        dom_t = max(c["t_compute"], c["t_memory"], c["t_collective"])
        Row.add(name, dom_t * 1e6,
                f"dom={c['dominant']} comp={c['t_compute']:.3e}s "
                f"mem={c['t_memory']:.3e}s coll={c['t_collective']:.3e}s "
                f"useful={c['useful_ratio']:.2f} roofline_frac={c['roofline_fraction']:.3f}")


def markdown_table(cells: List[Dict[str, Any]]) -> str:
    lines = ["| arch | shape | compute s | compute* s | memory s | collective s | dominant | MODEL/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['t_compute_naive']:.3e} | "
            f"{c['t_compute']:.3e} | {c['t_memory']:.3e} | {c['t_collective']:.3e} | "
            f"**{c['dominant']}** | {c['useful_ratio']:.2f} | {c['roofline_fraction']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    run()
    print()
    print(markdown_table(all_cells("single")))
