"""Table 1 proxy: accuracy of FP=xINT vs same-family baselines at
W4A4 / W2A4 / W2A2 across model families.

Methods (all calibration-free or one-shot, as in the paper's table):
  full        — FP reference
  ours        — multi-term series (policy per bit setting)
  rtn         — 1-term truncation of the same quantizer (= round-to-nearest)
  gptq_lite   — error-propagating one-shot weight quantizer + dynamic A-RTN

Derived column: held-out top-1 accuracy (the ImageNet-accuracy stand-in).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, eval_metrics, time_fn, trained_model
from repro.core.policy import ExpansionPolicy, NAMED_POLICIES
from repro.core.ptq import expand_params
from repro.models.layers import FP, QuantContext
from repro.quant.baselines import gptq_lite_quantize
from repro.train.data import make_batch

ARCHS = ("qwen2_1_5b", "granite_20b")
SETTINGS = ("w4a4", "w2a4", "w2a2")


def _rtn_policy(pol: ExpansionPolicy) -> ExpansionPolicy:
    import dataclasses
    return dataclasses.replace(pol, w_terms=1, a_terms=1, w_saturating=False,
                               first_last_terms=1)


def _gptq_params(cfg, params):
    """GPTQ-lite on every stacked GEMM weight (tiny calibration batch)."""
    import numpy as np
    r = np.random.default_rng(0)

    def visit(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if name.rsplit("/", 1)[-1] == "kernel" and leaf.ndim >= 2:
            k = leaf.shape[-2]
            x_cal = jnp.array(r.normal(size=(32, k)).astype("float32"))
            flat = leaf.reshape(-1, *leaf.shape[-2:])
            out = jnp.stack([gptq_lite_quantize(w, x_cal, 4) for w in flat])
            return out.reshape(leaf.shape)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)


def run():
    for arch in ARCHS:
        cfg, params = trained_model(arch)
        base = eval_metrics(cfg, params)
        Row.add(f"table1/{arch}/full_prec", 0.0, f"acc={base['accuracy']:.4f}")
        for setting in SETTINGS:
            pol = NAMED_POLICIES[setting]
            q = expand_params(params, pol)
            m = eval_metrics(cfg, q, QuantContext(policy=pol))
            Row.add(f"table1/{arch}/{setting}/ours", 0.0, f"acc={m['accuracy']:.4f}")
            rp = _rtn_policy(pol)
            mr = eval_metrics(cfg, expand_params(params, rp), QuantContext(policy=rp))
            Row.add(f"table1/{arch}/{setting}/rtn", 0.0, f"acc={mr['accuracy']:.4f}")
        # gptq-lite: weight-only 4-bit one-shot + dynamic 4-bit activations
        gp = _gptq_params(cfg, params)
        act_pol = ExpansionPolicy(w_bits=4, a_bits=4, w_terms=1, a_terms=1,
                                  w_saturating=False)
        mg = eval_metrics(cfg, gp)
        Row.add(f"table1/{arch}/w4/gptq_lite", 0.0, f"acc={mg['accuracy']:.4f}")


if __name__ == "__main__":
    run()
