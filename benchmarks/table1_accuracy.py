"""Table 1 proxy: accuracy of FP=xINT vs same-family baselines at
W4A4 / W2A4 / W2A2 across model families.

Methods (all calibration-free or one-shot, as in the paper's table), all
through the unified Recipe -> Artifact -> Runtime path — one code path for
every row:

  full        — FP reference
  ours        — multi-term series (``fpxint`` at the policy per bit setting)
  1term       — 1-term truncation of the same quantizer (= round-to-nearest
                in series form; isolates the win of the extra terms)
  rtn         — registry ``rtn``: min-max RTN FP reconstruction
  gptq_lite   — registry ``gptq_lite``: error-propagating one-shot weights

Derived column: held-out top-1 accuracy (the ImageNet-accuracy stand-in).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import Row, eval_artifact, eval_metrics, trained_model
from repro.api import QuantRecipe, quantize
from repro.core.policy import ExpansionPolicy, NAMED_POLICIES

ARCHS = ("qwen2_1_5b", "granite_20b")
SETTINGS = ("w4a4", "w2a4", "w2a2")


def _one_term(pol: ExpansionPolicy) -> ExpansionPolicy:
    return dataclasses.replace(pol, w_terms=1, a_terms=1, w_saturating=False,
                               first_last_terms=1)


def run():
    for arch in ARCHS:
        cfg, params = trained_model(arch)
        base = eval_metrics(cfg, params)
        Row.add(f"table1/{arch}/full_prec", 0.0, f"acc={base['accuracy']:.4f}")
        for setting in SETTINGS:
            pol = NAMED_POLICIES[setting]
            for label, recipe in (
                ("ours", QuantRecipe(method="fpxint", policy=pol, arch=arch)),
                ("1term", QuantRecipe(method="fpxint", policy=_one_term(pol),
                                      arch=arch)),
            ):
                art = quantize(params, recipe)
                m = eval_artifact(cfg, art)
                Row.add(f"table1/{arch}/{setting}/{label}", 0.0,
                        f"acc={m['accuracy']:.4f}")
        # one-shot weight baselines (4-bit, FP activations) — same artifact
        # type, same Runtime eval path as every other row
        for method in ("rtn", "gptq_lite"):
            art = quantize(params, QuantRecipe(
                method=method, policy=NAMED_POLICIES["w4a4"], arch=arch))
            m = eval_artifact(cfg, art)
            Row.add(f"table1/{arch}/w4/{method}", 0.0,
                    f"acc={m['accuracy']:.4f}")


if __name__ == "__main__":
    run()
