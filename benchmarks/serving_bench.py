"""Serving throughput: continuous slot batching vs legacy group-drain.

The workload is deliberately group-drain-hostile (and deployment-realistic):
prompt lengths follow a Zipf-ish mix of many distinct values, and per-request
token budgets vary, so the legacy scheduler fragments into many small
equal-length groups — each drained to completion with most of the batch
idle — while the slot scheduler keeps every slot busy by prefilling queued
requests into slots freed mid-stream.

Emits ``benchmarks/results/BENCH_serving.json``::

    {"workload": {...},
     "grouped": {"decode_tokens_per_sec": ..., "occupancy": ...},
     "slots":   {"decode_tokens_per_sec": ..., "occupancy": ...},
     "speedup_decode_tokens_per_sec": ...}

Run:  PYTHONPATH=src python benchmarks/serving_bench.py [--tiny]
(CPU wall-clock numbers; the occupancy/steps columns are backend-invariant.)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.infer.serve import Engine, ServeConfig
from repro.models import model as M

OUT_JSON = os.path.join(os.path.dirname(__file__), "results", "BENCH_serving.json")


def make_workload(cfg, n_requests: int, max_new: int, seed: int = 0):
    """Zipf-ish mixed-length prompts + varied token budgets.

    Lengths are drawn from a wide alphabet with Zipf(1.0) weights, so a few
    lengths dominate but the long tail guarantees many small/singleton
    groups for the grouped scheduler — its worst case (mean group size under
    half the batch), and the open-traffic common case."""
    rng = np.random.default_rng(seed)
    lengths = np.arange(4, 28)                      # 24 distinct lengths
    ranks = np.arange(1, len(lengths) + 1, dtype=np.float64)
    pz = ranks ** -1.0
    pz /= pz.sum()
    reqs = []
    for _ in range(n_requests):
        length = int(rng.choice(lengths, p=pz))
        budget = int(rng.integers(max(2, max_new // 2), max_new + 1))
        reqs.append((rng.integers(0, cfg.vocab_size, length).tolist(), budget))
    return reqs


def run_once(cfg, params, reqs, *, scheduler: str, slots: int, max_seq: int,
             max_new: int) -> dict:
    eng = Engine(cfg, params, serve_cfg=ServeConfig(
        max_seq=max_seq, max_batch=slots, max_slots=slots, scheduler=scheduler))
    for toks, budget in reqs:
        eng.add_request(toks, max_new_tokens=budget)
    t0 = time.perf_counter()
    out = eng.run(max_new_tokens=max_new)
    wall = time.perf_counter() - t0
    st = dict(eng.last_run_stats)
    st["wall_seconds"] = wall
    st["tokens_per_sec"] = st["generated_tokens"] / wall if wall > 0 else 0.0
    ttfts = [m["ttft_s"] for m in eng.last_request_metrics.values()]
    st["ttft_mean_s"] = float(np.mean(ttfts)) if ttfts else 0.0
    st["ttft_max_s"] = float(np.max(ttfts)) if ttfts else 0.0
    st["n_outputs"] = len(out)
    return st


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized run (fewer requests/tokens)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args(argv)
    if args.tiny:
        args.requests, args.max_new = 10, 6

    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    reqs = make_workload(cfg, args.requests, args.max_new, seed=args.seed)

    results = {}
    for scheduler in ("grouped", "slots"):
        # warmup pass compiles every (scheduler, shape) kernel; the timed
        # pass measures steady-state serving
        run_once(cfg, params, reqs, scheduler=scheduler, slots=args.slots,
                 max_seq=args.max_seq, max_new=args.max_new)
        results[scheduler] = run_once(
            cfg, params, reqs, scheduler=scheduler, slots=args.slots,
            max_seq=args.max_seq, max_new=args.max_new)
        st = results[scheduler]
        print(f"{scheduler:8s}: {st['generated_tokens']} tokens, "
              f"occupancy {st['occupancy']:.2f}, "
              f"decode {st['decode_tokens_per_sec']:.1f} tok/s, "
              f"wall {st['wall_seconds']:.2f}s")

    speedup = (results["slots"]["decode_tokens_per_sec"]
               / max(results["grouped"]["decode_tokens_per_sec"], 1e-9))
    payload = {
        "arch": "qwen2_1_5b (smoke)",
        "backend": "cpu",
        "note": "wall-clock on the CI/container CPU backend; occupancy and "
                "decode_steps are backend-invariant scheduler properties",
        "workload": {
            "requests": args.requests,
            "distinct_prompt_lengths": len({len(t) for t, _ in reqs}),
            "length_distribution": "zipf(1.0) over [4..27]",
            "max_new_tokens": args.max_new,
            "slots": args.slots,
            "max_seq": args.max_seq,
        },
        "grouped": results["grouped"],
        "slots": results["slots"],
        "speedup_decode_tokens_per_sec": speedup,
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"slots/grouped decode speedup: {speedup:.2f}x", file=sys.stderr)
    print(f"wrote {args.out}", file=sys.stderr)
    return payload


if __name__ == "__main__":
    main()
