"""Serving throughput: continuous slot batching vs legacy group-drain,
plus the paged-KV benchmark and an open-loop Poisson arrival mode.

The closed-loop workload is deliberately group-drain-hostile (and
deployment-realistic): prompt lengths follow a Zipf-ish mix of many distinct
values, and per-request token budgets vary, so the legacy scheduler fragments
into many small equal-length groups — each drained to completion with most of
the batch idle — while the slot scheduler keeps every slot busy by prefilling
queued requests into slots freed mid-stream.

Default mode emits ``benchmarks/results/BENCH_serving.json``::

    {"workload": {...},
     "grouped": {"decode_tokens_per_sec": ..., "occupancy": ...},
     "slots":   {"decode_tokens_per_sec": ..., "occupancy": ...},
     "speedup_decode_tokens_per_sec": ...}

``--paged`` mode emits ``benchmarks/results/BENCH_paged.json`` instead:
dense-slots vs paged-slots on the same workload (token-identity asserted),
page-granular HBM accounting (kv_bytes_hwm vs the dense-equivalent
reservation, per-request footprints ∝ actual length), admitted-slots-at-
fixed-budget from the planner, and an **open-loop Poisson sweep**: requests
arrive with exponential inter-arrival gaps at each offered load (req/s) via
``Engine.add_request(..., arrival=t)``, and we report p50/p99 TTFT and
p50/p99 mean inter-token latency across requests at each rate.

Run:  PYTHONPATH=src python benchmarks/serving_bench.py [--tiny] [--paged]
(CPU wall-clock numbers; occupancy/steps/page counts are backend-invariant.)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.infer.serve import Engine, ServeConfig
from repro.models import model as M

OUT_JSON = os.path.join(os.path.dirname(__file__), "results", "BENCH_serving.json")
OUT_PAGED = os.path.join(os.path.dirname(__file__), "results", "BENCH_paged.json")
OUT_PREFIX = os.path.join(os.path.dirname(__file__), "results", "BENCH_prefix.json")


def make_workload(cfg, n_requests: int, max_new: int, seed: int = 0):
    """Zipf-ish mixed-length prompts + varied token budgets.

    Lengths are drawn from a wide alphabet with Zipf(1.0) weights, so a few
    lengths dominate but the long tail guarantees many small/singleton
    groups for the grouped scheduler — its worst case (mean group size under
    half the batch), and the open-traffic common case."""
    rng = np.random.default_rng(seed)
    lengths = np.arange(4, 28)                      # 24 distinct lengths
    ranks = np.arange(1, len(lengths) + 1, dtype=np.float64)
    pz = ranks ** -1.0
    pz /= pz.sum()
    reqs = []
    for _ in range(n_requests):
        length = int(rng.choice(lengths, p=pz))
        budget = int(rng.integers(max(2, max_new // 2), max_new + 1))
        reqs.append((rng.integers(0, cfg.vocab_size, length).tolist(), budget))
    return reqs


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """Open-loop arrival offsets (seconds from run start): cumulative sum of
    exponential inter-arrival gaps at ``rate`` requests/sec."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def _pct(xs, q: float) -> float:
    return float(np.percentile(xs, q)) if len(xs) else 0.0


def run_once(cfg, params, reqs, *, scheduler: str, slots: int, max_seq: int,
             max_new: int, paged: bool = False, page_size: int = 8,
             arrivals=None, **sc_extra):
    """One serving pass; returns ``(stats, outputs)``.

    ``arrivals`` (per-request second offsets) switches the run open-loop:
    requests become eligible at ``run_start + arrivals[i]`` instead of all
    sitting queued at t=0.  Extra keywords (``prefill_chunk``,
    ``prefix_cache``, ...) pass through to :class:`ServeConfig`.

    ``warmup=True`` first drives a throwaway mini-run on the SAME engine so
    XLA compilation of the fused steps lands outside the timed window (jit
    caches are per-engine closures — warming a separate engine instance
    does nothing).  The prefix trie is cleared at every run end
    (``release_all``), so the timed run still starts with a cold cache."""
    warmup = sc_extra.pop("warmup", False)
    eng = Engine(cfg, params, serve_cfg=ServeConfig(
        max_seq=max_seq, max_batch=slots, max_slots=slots, scheduler=scheduler,
        paged=paged, page_size=page_size, **sc_extra))
    if warmup:
        for _ in range(2):
            eng.add_request(list(range(1, 2 * page_size + 4)),
                            max_new_tokens=2)
        eng.run(max_new_tokens=2)
    for i, (toks, budget) in enumerate(reqs):
        arr = float(arrivals[i]) if arrivals is not None else 0.0
        eng.add_request(toks, max_new_tokens=budget, arrival=arr)
    t0 = time.perf_counter()
    out = eng.run(max_new_tokens=max_new)
    wall = time.perf_counter() - t0
    st = dict(eng.last_run_stats)
    st["wall_seconds"] = wall
    st["tokens_per_sec"] = st["generated_tokens"] / wall if wall > 0 else 0.0
    mets = list(eng.last_request_metrics.values())
    ttfts = [m["ttft_s"] for m in mets]
    itls = [m["itl_s"] for m in mets if m.get("itl_s", 0.0) > 0.0]
    st["ttft_mean_s"] = float(np.mean(ttfts)) if ttfts else 0.0
    st["ttft_max_s"] = float(np.max(ttfts)) if ttfts else 0.0
    st["ttft_p50_s"] = _pct(ttfts, 50)
    st["ttft_p99_s"] = _pct(ttfts, 99)
    st["itl_p50_s"] = _pct(itls, 50)
    st["itl_p99_s"] = _pct(itls, 99)
    st["n_outputs"] = len(out)
    return st, out


def paged_bench(args):
    """Paged-vs-dense serving comparison + open-loop Poisson sweep.

    Emits ``BENCH_paged.json``: token identity (asserted), page-granular HBM
    accounting (peak pages vs dense-equivalent reservation, per-request
    footprints ∝ actual length), admitted-slots-at-fixed-budget from the
    planner, modeled per-step KV read traffic, and p50/p99 TTFT +
    inter-token latency at each offered load."""
    from repro.infer import kvcache
    from repro.infer.scheduler import plan_slots

    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    reqs = make_workload(cfg, args.requests, args.max_new, seed=args.seed)
    common = dict(scheduler="slots", slots=args.slots, max_seq=args.max_seq,
                  max_new=args.max_new)
    page = args.page_size

    # -- closed-loop: dense vs paged on the identical workload ------------
    run_once(cfg, params, reqs, **common)                       # warmup
    st_dense, out_dense = run_once(cfg, params, reqs, **common)
    run_once(cfg, params, reqs, paged=True, page_size=page, **common)
    st_paged, out_paged = run_once(cfg, params, reqs, paged=True,
                                   page_size=page, **common)
    norm = lambda o: {r: [int(t) for t in v] for r, v in o.items()}
    token_identical = norm(out_dense) == norm(out_paged)
    assert token_identical, "paged engine diverged from dense (greedy)"
    print(f"dense : {st_dense['generated_tokens']} tokens, "
          f"wall {st_dense['wall_seconds']:.2f}s")
    print(f"paged : {st_paged['generated_tokens']} tokens, "
          f"wall {st_paged['wall_seconds']:.2f}s, "
          f"pages_hwm {st_paged['paged']['pages_hwm']}"
          f"/{st_paged['paged']['num_pages']}")

    # -- accounting: per-request KV footprint ∝ actual length -------------
    pb = kvcache.page_bytes(cfg, page)
    mp = kvcache.pages_for(args.max_seq, page)
    footprints = []
    for toks, budget in reqs[:8]:
        total = min(len(toks) + budget, args.max_seq)
        footprints.append({
            "prompt_len": len(toks), "max_new": budget, "kv_len": total,
            "kv_bytes_paged": kvcache.pages_for(total, page) * pb,
            "kv_bytes_dense": mp * pb,
        })

    # -- admission: slots a fixed HBM budget buys, dense vs paged ---------
    pbytes = kvcache.param_bytes_per_device(params)
    per_seq = kvcache.total_cache_bytes(cfg, 1, args.max_seq)
    budget_bytes = pbytes + 4.0 * per_seq          # room for 4 dense seqs
    mk = lambda paged: ServeConfig(
        max_seq=args.max_seq, max_batch=64, max_slots=64, scheduler="slots",
        hbm_budget_bytes=budget_bytes, paged=paged, page_size=page)
    slots_dense = plan_slots(cfg, mk(False), params)
    slots_paged = plan_slots(cfg, mk(True), params)
    print(f"admission @ params+4seq budget: dense {slots_dense} slots, "
          f"paged {slots_paged} slots")

    # -- modeled per-step KV read traffic (backend-invariant) -------------
    # full-cache attention reads the resident KV every decode step: dense
    # streams max_seq rows per slot regardless of fill; paged streams only
    # the pages the sequence actually occupies (rounded up to page_size)
    dense_reads = paged_reads = 0
    for toks, budget in reqs:
        for t in range(1, budget + 1):
            cur = min(len(toks) + t, args.max_seq)
            paged_reads += -(-cur // page) * page
            dense_reads += args.max_seq
    traffic_reduction = 1.0 - paged_reads / max(dense_reads, 1)

    # -- open-loop Poisson sweep ------------------------------------------
    rates = [float(r) for r in args.rates.split(",") if r.strip()]
    sweep = []
    for rate in rates:
        arr = poisson_arrivals(len(reqs), rate, seed=args.seed)
        st, out = run_once(cfg, params, reqs, paged=True, page_size=page,
                           arrivals=arr, **common)
        assert norm(out) == norm(out_dense), \
            f"open-loop paged run diverged at rate {rate}"
        sweep.append({
            "offered_rate_req_per_s": rate,
            "ttft_p50_s": st["ttft_p50_s"], "ttft_p99_s": st["ttft_p99_s"],
            "itl_p50_s": st["itl_p50_s"], "itl_p99_s": st["itl_p99_s"],
            "tokens_per_sec": st["tokens_per_sec"],
            "pages_hwm": st["paged"]["pages_hwm"],
        })
        print(f"poisson {rate:5.1f} req/s: ttft p50 {st['ttft_p50_s']:.3f}s "
              f"p99 {st['ttft_p99_s']:.3f}s, itl p50 {st['itl_p50_s']*1e3:.1f}ms "
              f"p99 {st['itl_p99_s']*1e3:.1f}ms")

    pg = st_paged["paged"]
    payload = {
        "arch": "qwen2_1_5b (smoke)",
        "backend": "cpu",
        "note": "wall-clock on the CI/container CPU backend; page counts, "
                "admission slots and modeled traffic are backend-invariant",
        "workload": {
            "requests": args.requests,
            "length_distribution": "zipf(1.0) over [4..27]",
            "max_new_tokens": args.max_new,
            "slots": args.slots, "max_seq": args.max_seq,
            "page_size": page,
        },
        "token_identical": token_identical,
        "dense": st_dense,
        "paged": st_paged,
        "hbm": {
            "page_bytes": pb,
            "kv_bytes_hwm_paged": pg["kv_bytes_hwm"],
            "kv_bytes_dense_equivalent": pg["kv_bytes_dense"],
            "kv_hbm_reduction": 1.0 - pg["kv_bytes_hwm"]
                                      / max(pg["kv_bytes_dense"], 1e-9),
            "per_request_footprints": footprints,
        },
        "admission_at_fixed_budget": {
            "hbm_budget_bytes": budget_bytes,
            "dense_slots": slots_dense,
            "paged_slots": slots_paged,
        },
        "modeled_kv_read_traffic": {
            "dense_token_rows_read": dense_reads,
            "paged_token_rows_read": paged_reads,
            "reduction": traffic_reduction,
        },
        "poisson_sweep": sweep,
    }
    os.makedirs(os.path.dirname(args.paged_out), exist_ok=True)
    with open(args.paged_out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"kv HBM hwm reduction: {payload['hbm']['kv_hbm_reduction']:.1%}, "
          f"modeled read-traffic reduction: {traffic_reduction:.1%}",
          file=sys.stderr)
    print(f"wrote {args.paged_out}", file=sys.stderr)
    return payload


def prefix_bench(args):
    """Shared-system-prompt workload: N requests with one common page-aligned
    prefix, open-loop Poisson arrivals, prefix cache ON vs OFF (both chunked,
    both paged — isolating page sharing itself).

    Emits ``BENCH_prefix.json``: p50/p99 TTFT for both runs, prompt tokens
    computed vs reused, pages high-water mark, and the common-prefix reuse
    fraction (asserted >= 90%: every admission after the first cold fill
    must warm-hit the trie).  Token identity with the cache OFF is asserted
    — sharing pages must not change a single generated token."""
    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    plen, n = args.prefix_len, args.prefix_requests
    assert plen % args.page_size == 0, "common prefix must be page-aligned"
    common = rng.integers(0, cfg.vocab_size, plen).tolist()
    reqs = []
    for _ in range(n):
        sfx = int(rng.integers(2, args.suffix_max + 1))
        reqs.append((common + rng.integers(0, cfg.vocab_size, sfx).tolist(),
                     args.max_new))
    arr = poisson_arrivals(n, args.rate, seed=args.seed)
    kw = dict(scheduler="slots", slots=args.slots, max_seq=args.max_seq,
              max_new=args.max_new, paged=True, page_size=args.page_size,
              arrivals=arr, prefill_chunk=args.prefill_chunk)

    runs = {}
    outs = {}
    for label, on in (("prefix_off", False), ("prefix_on", True)):
        runs[label], outs[label] = run_once(cfg, params, reqs,
                                            prefix_cache=on, warmup=True,
                                            **kw)
        st = runs[label]
        pfx = st.get("prefix", {})
        print(f"{label:10s}: ttft p50 {st['ttft_p50_s']:.3f}s "
              f"p99 {st['ttft_p99_s']:.3f}s, "
              f"computed {pfx.get('tokens_computed', 0)}, "
              f"reused {pfx.get('tokens_reused', 0)}, "
              f"pages_hwm {st['paged']['pages_hwm']}")

    norm = lambda o: {r: [int(t) for t in v] for r, v in o.items()}
    token_identical = norm(outs["prefix_off"]) == norm(outs["prefix_on"])
    assert token_identical, "prefix-cached run diverged from uncached"

    # every request after the cold first can reuse the whole common prefix
    reusable = (n - 1) * plen
    reused = runs["prefix_on"]["prefix"]["tokens_reused"]
    reuse_fraction = reused / max(reusable, 1)
    assert reuse_fraction >= 0.9, \
        f"reused only {reused}/{reusable} common-prefix tokens"
    assert runs["prefix_on"]["paged"]["pages_in_use_end"] == 0, "page leak"

    payload = {
        "arch": "qwen2_1_5b (smoke)",
        "backend": "cpu",
        "note": "wall-clock on the CI/container CPU backend; reuse counts "
                "and page high-water marks are backend-invariant",
        "workload": {
            "requests": n, "common_prefix_tokens": plen,
            "suffix_tokens": f"uniform[2..{args.suffix_max}]",
            "max_new_tokens": args.max_new, "slots": args.slots,
            "max_seq": args.max_seq, "page_size": args.page_size,
            "prefill_chunk": args.prefill_chunk,
            "poisson_rate_req_per_s": args.rate,
        },
        "token_identical": token_identical,
        "prefix_off": runs["prefix_off"],
        "prefix_on": runs["prefix_on"],
        "reuse": {
            "reusable_common_prefix_tokens": reusable,
            "tokens_reused": reused,
            "reuse_fraction": reuse_fraction,
            "tokens_computed_off":
                runs["prefix_off"]["prefix"]["tokens_computed"],
            "tokens_computed_on":
                runs["prefix_on"]["prefix"]["tokens_computed"],
        },
        "ttft_p99_improved": (runs["prefix_on"]["ttft_p99_s"]
                              < runs["prefix_off"]["ttft_p99_s"]),
        "pages_hwm_off": runs["prefix_off"]["paged"]["pages_hwm"],
        "pages_hwm_on": runs["prefix_on"]["paged"]["pages_hwm"],
    }
    os.makedirs(os.path.dirname(args.prefix_out), exist_ok=True)
    with open(args.prefix_out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"common-prefix reuse: {reuse_fraction:.1%}, ttft p99 "
          f"{runs['prefix_off']['ttft_p99_s']:.3f}s -> "
          f"{runs['prefix_on']['ttft_p99_s']:.3f}s", file=sys.stderr)
    print(f"wrote {args.prefix_out}", file=sys.stderr)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized run (fewer requests/tokens)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=OUT_JSON)
    ap.add_argument("--paged", action="store_true",
                    help="run the paged-KV benchmark (emits BENCH_paged.json)")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--rates", default="2,8",
                    help="comma-separated offered loads (req/s) for the "
                         "open-loop Poisson sweep in --paged mode")
    ap.add_argument("--paged-out", default=OUT_PAGED)
    ap.add_argument("--prefix", action="store_true",
                    help="run the shared-prefix benchmark "
                         "(emits BENCH_prefix.json)")
    ap.add_argument("--prefix-len", type=int, default=256,
                    help="common prefix length (page-aligned)")
    ap.add_argument("--prefix-requests", type=int, default=16)
    ap.add_argument("--suffix-max", type=int, default=24)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--rate", type=float, default=4.0,
                    help="Poisson offered load (req/s) in --prefix mode")
    ap.add_argument("--prefix-out", default=OUT_PREFIX)
    args = ap.parse_args(argv)
    if args.tiny:
        args.requests, args.max_new = 10, 6
        if args.prefix:
            args.prefix_len, args.prefix_requests = 32, 6
            args.suffix_max, args.max_new = 8, 4
            args.max_seq, args.page_size = 64, 8
            args.prefill_chunk, args.slots = 8, 2

    if args.prefix:
        if args.prefix and not args.tiny:
            args.max_seq = max(args.max_seq,
                               args.prefix_len + args.suffix_max
                               + args.max_new + args.page_size)
            args.max_seq = -(-args.max_seq // args.page_size) * args.page_size
        return prefix_bench(args)

    if args.paged:
        return paged_bench(args)

    cfg = get_arch("qwen2_1_5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    reqs = make_workload(cfg, args.requests, args.max_new, seed=args.seed)

    results = {}
    for scheduler in ("grouped", "slots"):
        # warmup pass compiles every (scheduler, shape) kernel; the timed
        # pass measures steady-state serving
        run_once(cfg, params, reqs, scheduler=scheduler, slots=args.slots,
                 max_seq=args.max_seq, max_new=args.max_new)
        results[scheduler], _ = run_once(
            cfg, params, reqs, scheduler=scheduler, slots=args.slots,
            max_seq=args.max_seq, max_new=args.max_new)
        st = results[scheduler]
        print(f"{scheduler:8s}: {st['generated_tokens']} tokens, "
              f"occupancy {st['occupancy']:.2f}, "
              f"decode {st['decode_tokens_per_sec']:.1f} tok/s, "
              f"wall {st['wall_seconds']:.2f}s")

    speedup = (results["slots"]["decode_tokens_per_sec"]
               / max(results["grouped"]["decode_tokens_per_sec"], 1e-9))
    payload = {
        "arch": "qwen2_1_5b (smoke)",
        "backend": "cpu",
        "note": "wall-clock on the CI/container CPU backend; occupancy and "
                "decode_steps are backend-invariant scheduler properties",
        "workload": {
            "requests": args.requests,
            "distinct_prompt_lengths": len({len(t) for t, _ in reqs}),
            "length_distribution": "zipf(1.0) over [4..27]",
            "max_new_tokens": args.max_new,
            "slots": args.slots,
            "max_seq": args.max_seq,
        },
        "grouped": results["grouped"],
        "slots": results["slots"],
        "speedup_decode_tokens_per_sec": speedup,
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"slots/grouped decode speedup: {speedup:.2f}x", file=sys.stderr)
    print(f"wrote {args.out}", file=sys.stderr)
    return payload


if __name__ == "__main__":
    main()
