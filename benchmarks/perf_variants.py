"""Render every tagged dry-run variant (the §Perf iteration artifacts) as a
table — the machine-readable companion to EXPERIMENTS.md §Perf.

    PYTHONPATH=src:. python -m benchmarks.perf_variants
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import RESULTS_DIR, analyze_cell


def run():
    from benchmarks.common import Row

    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if not rec.get("ok"):
            continue
        c = analyze_cell(rec)
        tag = rec.get("tag") or "baseline"
        if rec["mesh"] != "single":
            continue
        rows.append((c["arch"], c["shape"], tag, c))
    # only cells that have at least one non-baseline variant
    varied = {(a, s) for a, s, t, _ in rows if t != "baseline"}
    for a, s, t, c in rows:
        if (a, s) not in varied:
            continue
        Row.add(f"perf/{a}/{s}/{t}",
                max(c["t_compute"], c["t_memory"], c["t_collective"]) * 1e6,
                f"comp*={c['t_compute']:.3e} mem={c['t_memory']:.3e} "
                f"coll={c['t_collective']:.3e} int={c['int_dot_flops']:.2e}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
